//! Minimal CLI argument parser (no `clap` in the vendored set).
//!
//! Supports `command [--flag] [--key value] [--set section.key=value]`
//! with typed accessors and a generated usage message.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--flag` (value = "true") options.
    options: BTreeMap<String, String>,
    /// Repeated `--set k=v` overrides.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if name == "set" {
                    let Some(kv) = it.next() else {
                        bail!("--set requires key=value");
                    };
                    let Some((k, v)) = kv.split_once('=') else {
                        bail!("--set expects key=value, got '{kv}'");
                    };
                    args.overrides.push((k.to_string(), v.to_string()));
                    continue;
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.options.insert(name.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_options_positionals() {
        let a = parse("build --n 5000 --family gist out.bin --verbose");
        assert_eq!(a.command.as_deref(), Some("build"));
        assert_eq!(a.get("n"), Some("5000"));
        assert_eq!(a.get("family"), Some("gist"));
        assert_eq!(a.positional, vec!["out.bin"]);
        assert!(a.get_flag("verbose"));
        assert!(!a.get_flag("quiet"));
    }

    #[test]
    fn parses_equals_form_and_sets() {
        let a = parse("run --n=100 --set merge.k=64 --set dataset.n=9");
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(
            a.overrides,
            vec![
                ("merge.k".to_string(), "64".to_string()),
                ("dataset.n".to_string(), "9".to_string())
            ]
        );
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 42");
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let b = parse("x --n abc");
        assert!(b.get_usize("n", 0).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(["--set".to_string()]).is_err());
        assert!(Args::parse(["--set".to_string(), "noequals".to_string()]).is_err());
    }
}

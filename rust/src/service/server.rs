//! `serve` mode: a thread-per-connection TCP listener speaking the
//! [`wire`] `KSRV` frame protocol over a shared [`Service`].
//!
//! The listener accepts with a non-blocking poll and every connection
//! socket carries a short read timeout, so a stop signal (a `Shutdown`
//! frame from any client, or [`ServerHandle::shutdown`]) drains the
//! whole server within one timeout tick: the accept loop closes, every
//! connection thread notices the flag at its next poll and is joined,
//! and the periodic checkpoint thread is stopped — no detached threads
//! survive.
//!
//! The server owns no engine logic: admission control, degradation,
//! and instrumentation all live in [`Service`], so the CLI batch
//! driver and this listener exercise the identical surface.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::wire::{self, ClientFrame, ServerFrame};
use super::{MetricsDumper, Request, Response, Service};
use crate::cli::Args;
use crate::config::{ConfigMap, RunConfig};
use crate::stream::{persist::RestoreOptions, StreamingIndex};

/// Listener options (the admission knobs live in
/// [`ServeConfig`](crate::config::ServeConfig) on the [`Service`]).
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Bind address, e.g. `127.0.0.1:0` for an ephemeral port.
    pub addr: String,
    /// Per-socket read timeout: the drain-notice latency of idle
    /// connections, and the patience for a peer stalled mid-frame.
    pub read_timeout: Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            addr: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_millis(100),
        }
    }
}

/// A running server; dropping it drains and joins everything.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    ckpt_tx: Option<mpsc::Sender<()>>,
    ckpt: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Has a shutdown been requested (by a client frame or locally)?
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Block until a client sends `Shutdown` (or `shutdown()` is
    /// called from another thread), then drain.
    pub fn wait(&mut self) {
        while !self.stopped() {
            std::thread::sleep(Duration::from_millis(20));
        }
        self.shutdown();
    }

    /// [`wait`](ServerHandle::wait), but stop the server ourselves
    /// after `limit` if no client did first.
    pub fn wait_with_deadline(&mut self, limit: Duration) {
        let deadline = Instant::now() + limit;
        while !self.stopped() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        self.shutdown();
    }

    /// Stop accepting, join every connection thread, stop the
    /// checkpoint ticker. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(join) = self.accept.take() {
            let _ = join.join();
        }
        self.ckpt_tx.take(); // closing the channel wakes the ticker
        if let Some(join) = self.ckpt.take() {
            let _ = join.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind and start serving `svc`. Returns once the listener is live.
pub fn spawn(svc: Arc<Service>, opts: &ServerOptions) -> Result<ServerHandle> {
    let listener =
        TcpListener::bind(&opts.addr).with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr().context("local_addr")?;
    listener
        .set_nonblocking(true)
        .context("set_nonblocking on listener")?;
    let stop = Arc::new(AtomicBool::new(false));

    let accept = {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let read_timeout = opts.read_timeout;
        std::thread::spawn(move || {
            // The accept thread owns the connection handles: no shared
            // registry lock, and drain = this loop joining its own list.
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((sock, _peer)) => {
                        let _ = sock.set_nodelay(true);
                        let _ = sock.set_read_timeout(Some(read_timeout));
                        let svc = Arc::clone(&svc);
                        let stop = Arc::clone(&stop);
                        conns.push(std::thread::spawn(move || {
                            serve_conn(&svc, &stop, sock);
                        }));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
            for join in conns {
                let _ = join.join();
            }
        })
    };

    // Periodic checkpoint hook: only when the service has both a
    // directory and a positive interval configured. With the WAL
    // attached this is cheap — sealed segments were already spilled
    // eagerly at publish, so a tick is mostly a manifest roll plus a
    // WAL truncate, not a bulk segment rewrite.
    let interval = svc.config().checkpoint_interval_s;
    let (ckpt_tx, ckpt) = if interval > 0.0 && svc.checkpoint_dir().is_some() {
        let (tx, rx) = mpsc::channel::<()>();
        let svc = Arc::clone(&svc);
        let every = Duration::from_secs_f64(interval);
        let join = std::thread::spawn(move || loop {
            match rx.recv_timeout(every) {
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Response::Error { message } = svc.handle(Request::Checkpoint) {
                        eprintln!("periodic checkpoint failed: {message}");
                    }
                }
                // Stop signal or sender dropped: shut down.
                _ => break,
            }
        });
        (Some(tx), Some(join))
    } else {
        (None, None)
    };

    Ok(ServerHandle {
        addr,
        stop,
        accept: Some(accept),
        ckpt_tx,
        ckpt,
    })
}

/// One connection: frames in, frames out, until EOF, a broken frame
/// stream, or server drain.
fn serve_conn(svc: &Service, stop: &AtomicBool, mut sock: TcpStream) {
    loop {
        // Poll for the first header byte so an idle connection notices
        // drain within one read timeout; the rest of the frame is then
        // read under the same timeout (a peer stalled mid-frame is a
        // broken connection, not an idle one).
        let first = loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let mut b = [0u8; 1];
            match sock.read(&mut b) {
                Ok(0) => return, // clean EOF
                Ok(_) => break b[0],
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    continue
                }
                Err(_) => return,
            }
        };
        let raw = match wire::read_raw_after(first, &mut sock) {
            Ok(raw) => raw,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Header-level garbage (bad magic/version/length): the
                // byte stream is desynchronized — answer once, close.
                let err = ServerFrame::Response(Response::Error {
                    message: e.to_string(),
                });
                let _ = sock.write_all(&wire::encode_server(&err));
                return;
            }
            Err(_) => return, // timeout mid-frame, EOF, reset
        };
        let reply = match wire::decode_client(&raw) {
            Ok(ClientFrame::Shutdown) => {
                let _ = sock.write_all(&wire::encode_server(&ServerFrame::ShuttingDown));
                stop.store(true, Ordering::Relaxed);
                return;
            }
            Ok(ClientFrame::Request(req)) => ServerFrame::Response(svc.handle(req)),
            // Payload-level garbage: framing is still aligned (the
            // payload was length-prefixed), so answer and keep serving.
            Err(e) => ServerFrame::Response(Response::Error {
                message: format!("{e:#}"),
            }),
        };
        if sock.write_all(&wire::encode_server(&reply)).is_err() {
            return;
        }
    }
}

/// A blocking client for the `KSRV` protocol (benches, tests, and the
/// smoke harness; any language can speak the 12-byte frame header).
pub struct ServeClient {
    sock: TcpStream,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient> {
        let sock = TcpStream::connect(addr).context("connect to serve addr")?;
        sock.set_nodelay(true).context("set_nodelay")?;
        Ok(ServeClient { sock })
    }

    /// Issue one request and read its response.
    pub fn request(&mut self, req: Request) -> Result<Response> {
        self.sock
            .write_all(&wire::encode_client(&ClientFrame::Request(req)))
            .context("write request frame")?;
        let raw = wire::read_raw(&mut self.sock).context("read response frame")?;
        match wire::decode_server(&raw)? {
            ServerFrame::Response(resp) => Ok(resp),
            ServerFrame::ShuttingDown => bail!("server is shutting down"),
        }
    }

    /// Ask the server to drain and stop; returns once acknowledged.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.sock
            .write_all(&wire::encode_client(&ClientFrame::Shutdown))
            .context("write shutdown frame")?;
        let raw = wire::read_raw(&mut self.sock).context("read shutdown ack")?;
        match wire::decode_server(&raw)? {
            ServerFrame::ShuttingDown => Ok(()),
            ServerFrame::Response(resp) => bail!("expected shutdown ack, got {resp:?}"),
        }
    }
}

// ---------------------------------------------------------------- CLI

/// The CLI `serve` subcommand: build or restore an index, wrap it in a
/// [`Service`], serve `KSRV` frames until a client sends `Shutdown`
/// (or `--max-seconds` elapses), then drain, checkpoint, and dump
/// metrics.
pub fn cli_serve(args: &Args) -> Result<()> {
    let mut map = match args.get("config") {
        Some(path) => ConfigMap::load(std::path::Path::new(path))?,
        None => ConfigMap::default(),
    };
    for (k, v) in &args.overrides {
        map.set(k, v);
    }
    let mut cfg = RunConfig::from_map(&map)?;
    if let Some(f) = args.get("family") {
        cfg.family = crate::dataset::DatasetFamily::from_name(f)
            .with_context(|| format!("unknown family '{f}'"))?;
    }
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let k = args.get_usize("k", cfg.merge.k)?;
    let lambda = args.get_usize("lambda", cfg.merge.lambda)?;
    cfg.stream.merge.k = k;
    cfg.stream.merge.lambda = lambda;
    cfg.stream.nnd.k = k;
    cfg.stream.nnd.lambda = lambda;
    cfg.stream.max_degree = args.get_usize("max-degree", cfg.stream.max_degree)?;
    cfg.stream.segment_size = args.get_usize("segment-size", cfg.stream.segment_size)?;
    cfg.stream.ef = args.get_usize("ef", cfg.stream.ef)?;
    cfg.stream.seal_threads = args.get_usize("seal-threads", cfg.stream.seal_threads)?;
    cfg.serve.max_inflight_search =
        args.get_usize("max-inflight-search", cfg.serve.max_inflight_search)?;
    cfg.serve.max_inflight_ingest =
        args.get_usize("max-inflight-ingest", cfg.serve.max_inflight_ingest)?;
    cfg.serve.max_seal_backlog = args.get_usize("max-seal-backlog", cfg.serve.max_seal_backlog)?;
    cfg.serve.retry_after_ms = args.get_u64("retry-after-ms", cfg.serve.retry_after_ms)?;
    cfg.serve.checkpoint_interval_s =
        args.get_f64("checkpoint-interval", cfg.serve.checkpoint_interval_s)?;
    cfg.stream.wal_group_commit_us =
        args.get_u64("wal-group-commit-us", cfg.stream.wal_group_commit_us)?;

    let checkpoint_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
    let preload = args.get_usize("preload", 0)?;
    let index = if args.get_flag("restore") {
        let Some(dir) = &checkpoint_dir else {
            bail!("--restore requires --checkpoint-dir");
        };
        let mut idx =
            StreamingIndex::restore(dir, cfg.stream.clone(), &RestoreOptions::default())
                .with_context(|| format!("restore from {dir:?}"))?;
        // Replay the WAL tail (acknowledged writes after the last
        // checkpoint) before the listener goes live.
        idx.attach_durability(dir)
            .with_context(|| format!("attach WAL in {dir:?}"))?;
        println!(
            "restored from {dir:?}: {} segments, {} live rows",
            idx.stats().live_segments,
            idx.live_len()
        );
        Arc::new(idx)
    } else {
        let dim = if preload > 0 {
            cfg.family.generate(1, cfg.seed).dim
        } else {
            args.get_usize("dim", 0)?
        };
        if dim == 0 {
            bail!("serve needs --dim <d>, --preload <n> (with --family), or --restore");
        }
        let mut idx = StreamingIndex::new(dim, cfg.metric, cfg.stream.clone());
        if let Some(dir) = &checkpoint_dir {
            // Durable from the first acknowledged frame.
            idx.attach_durability(dir)
                .with_context(|| format!("attach WAL in {dir:?}"))?;
        }
        Arc::new(idx)
    };

    let svc = Arc::new(
        Service::with_options(Arc::clone(&index), cfg.serve).with_checkpoint_dir(checkpoint_dir),
    );
    if preload > 0 {
        let ds = cfg.family.generate(preload, cfg.seed);
        for i in 0..ds.len() {
            // Preload through the service like any other client; the
            // gate is idle here, so Overloaded normally means seal
            // pressure and clears. The retry budget turns a gate that
            // never clears (e.g. zero configured permits) into a typed
            // startup error instead of a silent hang.
            match super::retry_overloaded(super::DEFAULT_RETRY_BUDGET, || {
                svc.handle(Request::Insert {
                    vector: ds.vector(i).to_vec(),
                })
            })? {
                Response::Inserted { .. } => {}
                Response::Error { message } => bail!("preload insert failed: {message}"),
                other => bail!("unexpected preload response: {other:?}"),
            }
        }
        svc.handle(Request::Flush);
        println!("preloaded {} x {} ({})", preload, index.dim(), cfg.family.name());
    }

    let compactor = (!args.get_flag("no-compactor"))
        .then(|| Arc::clone(&index).spawn_compactor(Duration::from_millis(10)));
    let dumper = match (
        args.get("metrics-out").map(std::path::PathBuf::from),
        args.get_f64("metrics-interval", 0.0)?,
    ) {
        (Some(path), secs) if secs > 0.0 => Some(MetricsDumper::spawn(
            Arc::clone(&index),
            path,
            Duration::from_secs_f64(secs),
        )),
        _ => None,
    };

    let opts = ServerOptions {
        addr: args.get("addr").unwrap_or("127.0.0.1:7700").to_string(),
        ..Default::default()
    };
    let mut server = spawn(Arc::clone(&svc), &opts)?;
    println!(
        "serving on {} (dim={}, KSRV v{}, max inflight search/ingest {}/{}, \
         seal backlog cap {})",
        server.addr(),
        index.dim(),
        wire::SERVE_VERSION,
        cfg.serve.max_inflight_search,
        cfg.serve.max_inflight_ingest,
        cfg.serve.max_seal_backlog,
    );
    let _ = io::stdout().flush();

    let max_seconds = args.get_f64("max-seconds", 0.0)?;
    if max_seconds > 0.0 {
        server.wait_with_deadline(Duration::from_secs_f64(max_seconds));
    } else {
        server.wait();
    }
    println!("draining: listener closed, connections joined");

    if let Some(handle) = compactor {
        handle.stop();
    }
    svc.handle(Request::Flush);
    if svc.checkpoint_dir().is_some() {
        match svc.handle(Request::Checkpoint) {
            Response::Checkpointed {
                segments,
                manifest_bytes,
                ..
            } => println!("final checkpoint: {segments} segments, manifest {manifest_bytes} B"),
            Response::Error { message } => eprintln!("final checkpoint failed: {message}"),
            other => eprintln!("unexpected checkpoint response: {other:?}"),
        }
    }
    if let Some(d) = dumper {
        d.stop();
    }
    if let Some(path) = args.get("metrics-out").map(std::path::PathBuf::from) {
        super::write_metrics(&index, &path)?;
        println!("metrics -> {path:?}");
    }
    let st = index.stats();
    println!(
        "served: {} inserted, {} deleted, {} segments live",
        st.inserted, st.deleted, st.live_segments
    );
    Ok(())
}

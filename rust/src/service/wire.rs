//! `KSRV` frame protocol: the length-prefixed wire format the `serve`
//! TCP listener speaks, built on the same [`util::le`] cursor
//! discipline as every other wire format in this crate.
//!
//! One frame per request or response:
//!
//! ```text
//! offset  size  field
//! 0       4     magic      u32 LE = 0x4B535256 ("KSRV" big-endian ASCII)
//! 4       2     version    u16 LE = 1
//! 6       1     kind       request/response discriminant (below)
//! 7       1     reserved   must be 0
//! 8       4     len        payload length in bytes, u32 LE
//! 12      len   payload    kind-specific, little-endian fields
//! ```
//!
//! Truncated, oversized, or mis-tagged frames fail with clean errors —
//! never a panic — so a hostile or confused peer cannot take down the
//! server. Payload decoders call [`Cursor::finish`], so trailing bytes
//! are corruption, same as the checkpoint formats.
//!
//! [`util::le`]: crate::util::le

use std::io::{self, Read, Write};

use anyhow::{bail, Context, Result};

use crate::stream::StreamStats;
use crate::util::le::{Cursor, PutLe};

use super::{Request, RequestClass, Response};

pub const SERVE_MAGIC: u32 = 0x4B53_5256; // "KSRV"
pub const SERVE_VERSION: u16 = 1;
/// Frame header bytes before the payload.
pub const HEADER_LEN: usize = 12;
/// Payload-size sanity cap (64 MiB): a corrupt length prefix must not
/// become an allocation bomb.
pub const MAX_PAYLOAD: u32 = 64 << 20;

// Request frame kinds (client -> server).
pub const KIND_SEARCH: u8 = 1;
pub const KIND_INSERT: u8 = 2;
pub const KIND_DELETE: u8 = 3;
pub const KIND_UPSERT: u8 = 4;
pub const KIND_FLUSH: u8 = 5;
pub const KIND_STATS: u8 = 6;
pub const KIND_METRICS: u8 = 7;
pub const KIND_CHECKPOINT: u8 = 8;
/// Connection-level: drain and stop the server (not a [`Request`]).
pub const KIND_SHUTDOWN: u8 = 9;

// Response frame kinds (server -> client): request kind | 0x80.
pub const KIND_HITS: u8 = 0x81;
pub const KIND_INSERTED: u8 = 0x82;
pub const KIND_DELETED: u8 = 0x83;
pub const KIND_UPSERTED: u8 = 0x84;
pub const KIND_FLUSHED: u8 = 0x85;
pub const KIND_STATS_RESP: u8 = 0x86;
pub const KIND_METRICS_RESP: u8 = 0x87;
pub const KIND_CHECKPOINTED: u8 = 0x88;
pub const KIND_OVERLOADED: u8 = 0xBE;
pub const KIND_ERROR: u8 = 0xBF;
pub const KIND_SHUTTING_DOWN: u8 = 0xC0;

/// A client-originated frame: a service request or the server-level
/// shutdown signal.
#[derive(Clone, Debug)]
pub enum ClientFrame {
    Request(Request),
    Shutdown,
}

/// A server-originated frame: a service response or the shutdown ack.
#[derive(Clone, Debug)]
pub enum ServerFrame {
    Response(Response),
    ShuttingDown,
}

/// A parsed frame header + raw payload, transport-agnostic.
#[derive(Clone, Debug)]
pub struct RawFrame {
    pub kind: u8,
    pub payload: Vec<u8>,
}

// ------------------------------------------------------------ framing

/// Assemble a complete frame (header + payload) for the wire.
pub fn frame_bytes(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.put_u32(SERVE_MAGIC);
    out.put_u16(SERVE_VERSION);
    out.put_u8(kind);
    out.put_u8(0); // reserved
    out.put_u32(payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Parse a header whose first byte was already consumed (the
/// connection loop reads byte 0 separately so idle-poll timeouts never
/// land mid-header), then read the rest of the frame.
pub fn read_raw_after(first: u8, r: &mut impl Read) -> io::Result<RawFrame> {
    let mut header = [0u8; HEADER_LEN];
    header[0] = first;
    r.read_exact(&mut header[1..])?;
    // PANIC-OK: exact-length subslices of a fixed 12-byte header.
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != SERVE_MAGIC {
        return Err(bad(format!(
            "bad frame magic {magic:#010x} (expected KSRV {SERVE_MAGIC:#010x})"
        )));
    }
    // PANIC-OK: exact-length subslice of a fixed 12-byte header.
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != SERVE_VERSION {
        return Err(bad(format!(
            "unsupported KSRV frame version {version} (speak {SERVE_VERSION})"
        )));
    }
    let kind = header[6];
    if header[7] != 0 {
        return Err(bad(format!("reserved frame byte must be 0, got {}", header[7])));
    }
    // PANIC-OK: exact-length subslice of a fixed 12-byte header.
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(bad(format!("frame payload {len} B exceeds cap {MAX_PAYLOAD} B")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(RawFrame { kind, payload })
}

/// Read one complete frame from `r`.
pub fn read_raw(r: &mut impl Read) -> io::Result<RawFrame> {
    let mut first = [0u8; 1];
    r.read_exact(&mut first)?;
    read_raw_after(first[0], r)
}

/// Write a complete frame to `w`.
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> io::Result<()> {
    w.write_all(&frame_bytes(kind, payload))?;
    w.flush()
}

// ----------------------------------------------------------- requests

fn put_vector(buf: &mut Vec<u8>, v: &[f32]) {
    buf.put_u32(v.len() as u32);
    for &x in v {
        buf.put_f32(x);
    }
}

fn take_vector(cur: &mut Cursor<'_>) -> Result<Vec<f32>> {
    let len = cur.u32()? as usize;
    // The remaining-bytes check makes a hostile length fail before the
    // allocation, not after. checked_mul: on a 32-bit target a crafted
    // length near usize::MAX/4 would wrap the product under the
    // remaining() bound and sail past the guard.
    let fits = len
        .checked_mul(4)
        .filter(|&b| cur.remaining() >= b)
        .is_some();
    if !fits {
        bail!("vector length {len} exceeds frame payload");
    }
    (0..len).map(|_| cur.f32()).collect()
}

/// Encode a client frame (request or shutdown) for the wire.
pub fn encode_client(frame: &ClientFrame) -> Vec<u8> {
    let mut p: Vec<u8> = Vec::new();
    let kind = match frame {
        ClientFrame::Shutdown => KIND_SHUTDOWN,
        ClientFrame::Request(req) => match req {
            Request::Search { query, topk, ef } => {
                p.put_u32(*topk as u32);
                p.put_u32(*ef as u32);
                put_vector(&mut p, query);
                KIND_SEARCH
            }
            Request::Insert { vector } => {
                put_vector(&mut p, vector);
                KIND_INSERT
            }
            Request::Delete { gid } => {
                p.put_u32(*gid);
                KIND_DELETE
            }
            Request::Upsert { gid, vector } => {
                p.put_u32(*gid);
                put_vector(&mut p, vector);
                KIND_UPSERT
            }
            Request::Flush => KIND_FLUSH,
            Request::Stats => KIND_STATS,
            Request::MetricsSnapshot => KIND_METRICS,
            Request::Checkpoint => KIND_CHECKPOINT,
        },
    };
    frame_bytes(kind, &p)
}

/// Decode a client frame. Unknown kinds and malformed payloads are
/// clean errors the server answers with an `Error` frame.
pub fn decode_client(raw: &RawFrame) -> Result<ClientFrame> {
    let mut cur = Cursor::new(&raw.payload, "KSRV request payload");
    let frame = match raw.kind {
        KIND_SEARCH => {
            let topk = cur.u32()? as usize;
            let ef = cur.u32()? as usize;
            let query = take_vector(&mut cur)?;
            ClientFrame::Request(Request::Search { query, topk, ef })
        }
        KIND_INSERT => ClientFrame::Request(Request::Insert {
            vector: take_vector(&mut cur)?,
        }),
        KIND_DELETE => ClientFrame::Request(Request::Delete { gid: cur.u32()? }),
        KIND_UPSERT => {
            let gid = cur.u32()?;
            let vector = take_vector(&mut cur)?;
            ClientFrame::Request(Request::Upsert { gid, vector })
        }
        KIND_FLUSH => ClientFrame::Request(Request::Flush),
        KIND_STATS => ClientFrame::Request(Request::Stats),
        KIND_METRICS => ClientFrame::Request(Request::MetricsSnapshot),
        KIND_CHECKPOINT => ClientFrame::Request(Request::Checkpoint),
        KIND_SHUTDOWN => ClientFrame::Shutdown,
        k => bail!("unknown KSRV request kind {k:#04x}"),
    };
    cur.finish()?;
    Ok(frame)
}

// ---------------------------------------------------------- responses

/// Encode a server frame (response or shutdown ack) for the wire.
pub fn encode_server(frame: &ServerFrame) -> Vec<u8> {
    let mut p: Vec<u8> = Vec::new();
    let kind = match frame {
        ServerFrame::ShuttingDown => KIND_SHUTTING_DOWN,
        ServerFrame::Response(resp) => match resp {
            Response::Hits { hits, degraded } => {
                p.put_u8(*degraded as u8);
                p.put_u32(hits.len() as u32);
                for (dist, gid) in hits {
                    p.put_f32(*dist);
                    p.put_u32(*gid);
                }
                KIND_HITS
            }
            Response::Inserted { gid } => {
                p.put_u32(*gid);
                KIND_INSERTED
            }
            Response::Deleted { existed } => {
                p.put_u8(*existed as u8);
                KIND_DELETED
            }
            Response::Upserted { applied } => {
                p.put_u8(*applied as u8);
                KIND_UPSERTED
            }
            Response::Flushed => KIND_FLUSHED,
            Response::Stats(st) => {
                for v in [
                    st.inserted,
                    st.deleted,
                    st.upserts,
                    st.sealed,
                    st.compactions,
                    st.reclaimed,
                    st.seal_dropped,
                    st.live_segments,
                    st.memtable_len,
                    st.sealing,
                    st.tombstones,
                ] {
                    p.put_u64(v as u64);
                }
                KIND_STATS_RESP
            }
            Response::Metrics { json } => {
                p.put_u32(json.len() as u32);
                p.extend_from_slice(json.as_bytes());
                KIND_METRICS_RESP
            }
            Response::Checkpointed {
                segments,
                files_written,
                files_reused,
                gc_removed,
                memtable_rows,
                manifest_bytes,
            } => {
                for v in [
                    segments,
                    files_written,
                    files_reused,
                    gc_removed,
                    memtable_rows,
                    manifest_bytes,
                ] {
                    p.put_u64(*v);
                }
                KIND_CHECKPOINTED
            }
            Response::Overloaded {
                class,
                retry_after_ms,
            } => {
                p.put_u8(class.code());
                p.put_u64(*retry_after_ms);
                KIND_OVERLOADED
            }
            Response::Error { message } => {
                p.put_u32(message.len() as u32);
                p.extend_from_slice(message.as_bytes());
                KIND_ERROR
            }
        },
    };
    frame_bytes(kind, &p)
}

fn take_string(cur: &mut Cursor<'_>) -> Result<String> {
    let len = cur.u32()? as usize;
    let bytes = cur.take(len)?;
    String::from_utf8(bytes.to_vec()).context("KSRV string payload is not UTF-8")
}

/// Decode a server frame.
pub fn decode_server(raw: &RawFrame) -> Result<ServerFrame> {
    let mut cur = Cursor::new(&raw.payload, "KSRV response payload");
    let frame = match raw.kind {
        KIND_HITS => {
            let degraded = cur.u8()? != 0;
            let n = cur.u32()? as usize;
            // checked_mul mirrors take_vector: a wrapping product on
            // 32-bit targets must not bypass the pre-allocation guard.
            let fits = n
                .checked_mul(8)
                .filter(|&b| cur.remaining() >= b)
                .is_some();
            if !fits {
                bail!("hit count {n} exceeds frame payload");
            }
            let hits = (0..n)
                .map(|_| Ok((cur.f32()?, cur.u32()?)))
                .collect::<Result<Vec<_>>>()?;
            ServerFrame::Response(Response::Hits { hits, degraded })
        }
        KIND_INSERTED => ServerFrame::Response(Response::Inserted { gid: cur.u32()? }),
        KIND_DELETED => ServerFrame::Response(Response::Deleted {
            existed: cur.u8()? != 0,
        }),
        KIND_UPSERTED => ServerFrame::Response(Response::Upserted {
            applied: cur.u8()? != 0,
        }),
        KIND_FLUSHED => ServerFrame::Response(Response::Flushed),
        KIND_STATS_RESP => {
            let mut take = || -> Result<usize> { Ok(cur.u64()? as usize) };
            let st = StreamStats {
                inserted: take()?,
                deleted: take()?,
                upserts: take()?,
                sealed: take()?,
                compactions: take()?,
                reclaimed: take()?,
                seal_dropped: take()?,
                live_segments: take()?,
                memtable_len: take()?,
                sealing: take()?,
                tombstones: take()?,
            };
            ServerFrame::Response(Response::Stats(st))
        }
        KIND_METRICS_RESP => ServerFrame::Response(Response::Metrics {
            json: take_string(&mut cur)?,
        }),
        KIND_CHECKPOINTED => ServerFrame::Response(Response::Checkpointed {
            segments: cur.u64()?,
            files_written: cur.u64()?,
            files_reused: cur.u64()?,
            gc_removed: cur.u64()?,
            memtable_rows: cur.u64()?,
            manifest_bytes: cur.u64()?,
        }),
        KIND_OVERLOADED => {
            let code = cur.u8()?;
            let class = RequestClass::from_code(code)
                .with_context(|| format!("unknown request class code {code}"))?;
            ServerFrame::Response(Response::Overloaded {
                class,
                retry_after_ms: cur.u64()?,
            })
        }
        KIND_ERROR => ServerFrame::Response(Response::Error {
            message: take_string(&mut cur)?,
        }),
        KIND_SHUTTING_DOWN => ServerFrame::ShuttingDown,
        k => bail!("unknown KSRV response kind {k:#04x}"),
    };
    cur.finish()?;
    Ok(frame)
}

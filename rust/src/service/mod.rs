//! Transport-independent service layer over [`StreamingIndex`].
//!
//! Every request path into the engine — the `stream` batch driver, the
//! `serve` TCP server, tests, embedders — goes through one typed
//! surface: [`Request`] in, [`Response`] out, via [`Service::handle`].
//! The service owns what a transport must never re-implement:
//!
//! * **Admission control.** A bounded in-flight permit gate per
//!   request class, plus pressure probes (seal backlog, paged-memory
//!   residency). Ingest past the gate or at pressure 1.0 is rejected
//!   with [`Response::Overloaded`] and a retry-after hint. Searches
//!   are *never* rejected: past 50% pressure the beam width degrades
//!   linearly from the requested `ef` toward `topk`, trading recall
//!   for bounded latency instead of queueing.
//! * **Instrumentation.** Per-class `service.*` latency histograms,
//!   rejection/degradation counters, and in-flight gauges on the same
//!   [`Registry`] the engine records into, so one snapshot covers the
//!   whole request path.
//! * **Durability hooks.** `Checkpoint` requests (and the periodic
//!   checkpoint thread in `serve` mode) write to the service's
//!   configured directory — a client never names server paths.
//!
//! [`Service::handle`] never panics on malformed input (dimension
//! mismatches come back as [`Response::Error`]) and is `&self`: one
//! service is shared across connection threads.

pub mod server;
pub mod wire;

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::metrics::{Counter, Gauge, Histogram};
use crate::stream::{StreamStats, StreamingIndex};

/// One typed request into the engine surface.
#[derive(Clone, Debug)]
pub enum Request {
    /// k-NN search. `ef == 0` means "the engine's configured default";
    /// the effective beam width may degrade under pressure (see
    /// [`Response::Hits::degraded`]).
    Search {
        query: Vec<f32>,
        topk: usize,
        ef: usize,
    },
    /// Append a vector; the engine assigns the global id.
    Insert { vector: Vec<f32> },
    /// Tombstone a global id.
    Delete { gid: u32 },
    /// Replace the vector of a live global id.
    Upsert { gid: u32, vector: Vec<f32> },
    /// Seal the memtable and wait for in-flight builds.
    Flush,
    /// Point-in-time engine statistics.
    Stats,
    /// Full metrics-registry snapshot as schema-v1 JSON.
    MetricsSnapshot,
    /// Checkpoint to the service's configured directory.
    Checkpoint,
}

impl Request {
    /// The admission class this request is gated under.
    pub fn class(&self) -> RequestClass {
        match self {
            Request::Search { .. } => RequestClass::Search,
            Request::Insert { .. } => RequestClass::Insert,
            Request::Delete { .. } => RequestClass::Delete,
            Request::Upsert { .. } => RequestClass::Upsert,
            Request::Flush | Request::Stats | Request::MetricsSnapshot | Request::Checkpoint => {
                RequestClass::Control
            }
        }
    }
}

/// Typed reply to a [`Request`].
#[derive(Clone, Debug)]
pub enum Response {
    /// Search results (distance, gid), nearest first. `degraded` marks
    /// a search answered below the requested beam width.
    Hits { hits: Vec<(f32, u32)>, degraded: bool },
    Inserted { gid: u32 },
    /// `existed` is false when the gid was already dead or unknown.
    Deleted { existed: bool },
    /// `applied` is false when the gid was not live.
    Upserted { applied: bool },
    Flushed,
    Stats(StreamStats),
    /// Schema-v1 metrics snapshot, pretty-printed JSON.
    Metrics { json: String },
    Checkpointed {
        segments: u64,
        files_written: u64,
        files_reused: u64,
        gc_removed: u64,
        memtable_rows: u64,
        manifest_bytes: u64,
    },
    /// Ingest admission failed; retry after the hinted delay.
    Overloaded {
        class: RequestClass,
        retry_after_ms: u64,
    },
    /// The request was invalid or the operation failed. Never used for
    /// load shedding (that is `Overloaded`) and never a panic.
    Error { message: String },
}

/// Request classes of the permit gate (and the wire protocol).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    Search,
    Insert,
    Delete,
    Upsert,
    Control,
}

impl RequestClass {
    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Search => "search",
            RequestClass::Insert => "insert",
            RequestClass::Delete => "delete",
            RequestClass::Upsert => "upsert",
            RequestClass::Control => "control",
        }
    }

    /// Stable wire code (`wire::` Overloaded payloads).
    pub fn code(self) -> u8 {
        match self {
            RequestClass::Search => 0,
            RequestClass::Insert => 1,
            RequestClass::Delete => 2,
            RequestClass::Upsert => 3,
            RequestClass::Control => 4,
        }
    }

    pub fn from_code(code: u8) -> Option<RequestClass> {
        Some(match code {
            0 => RequestClass::Search,
            1 => RequestClass::Insert,
            2 => RequestClass::Delete,
            3 => RequestClass::Upsert,
            4 => RequestClass::Control,
            _ => return None,
        })
    }
}

/// Typed failure of a bounded retry loop: the service answered
/// [`Response::Overloaded`] on every one of the budgeted attempts.
/// The saturation is not clearing, so the caller must surface this
/// instead of spinning forever against a permanently full gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetriesExhausted {
    /// The admission class that kept being shed.
    pub class: RequestClass,
    /// How many attempts were made before giving up.
    pub attempts: u32,
}

impl std::fmt::Display for RetriesExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "service still overloaded ({}) after {} attempts",
            self.class.name(),
            self.attempts
        )
    }
}

impl std::error::Error for RetriesExhausted {}

/// Default attempt budget for [`retry_overloaded`] callers.
pub const DEFAULT_RETRY_BUDGET: u32 = 64;

/// Drive `op` until it stops answering [`Response::Overloaded`],
/// sleeping out the server's retry-after hint between attempts, for at
/// most `budget` attempts. A saturated service that never clears
/// surfaces a typed [`RetriesExhausted`] error instead of an
/// unbounded spin (the bug every ad-hoc retry loop used to have).
pub fn retry_overloaded(
    budget: u32,
    mut op: impl FnMut() -> Response,
) -> Result<Response, RetriesExhausted> {
    let mut attempts = 0u32;
    loop {
        match op() {
            Response::Overloaded {
                class,
                retry_after_ms,
            } => {
                attempts += 1;
                if attempts >= budget {
                    return Err(RetriesExhausted { class, attempts });
                }
                std::thread::sleep(Duration::from_millis(retry_after_ms.max(1)));
            }
            resp => return Ok(resp),
        }
    }
}

/// In-flight request counts behind the permit gate.
#[derive(Default)]
struct Inflight {
    search: usize,
    ingest: usize,
}

/// The transport-independent engine surface. Cheap to share
/// (`Arc<Service>`); all methods are `&self`.
pub struct Service {
    index: Arc<StreamingIndex>,
    cfg: ServeConfig,
    checkpoint_dir: Option<PathBuf>,
    // The permit gate sits strictly above every engine lock: handlers
    // bump the in-flight counts under `service.permits`, drop the
    // guard, and only then enter the engine (which starts its own
    // chain at `stream.compact`).
    // LOCK-ORDER: service.permits -> stream.compact
    // LOCK-ORDER: service.permits
    permits: Mutex<Inflight>,
    search_ns: Arc<Histogram>,
    insert_ns: Arc<Histogram>,
    delete_ns: Arc<Histogram>,
    upsert_ns: Arc<Histogram>,
    control_ns: Arc<Histogram>,
    rejected_insert: Arc<Counter>,
    rejected_delete: Arc<Counter>,
    rejected_upsert: Arc<Counter>,
    degraded_searches: Arc<Counter>,
    search_degradation: Arc<Histogram>,
    inflight_search: Arc<Gauge>,
    inflight_ingest: Arc<Gauge>,
}

/// RAII permit: decrements its class count (and gauge) on drop, so a
/// panicking engine call can never leak an in-flight slot.
struct Permit<'a> {
    svc: &'a Service,
    search: bool,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut st = self.svc.permits.lock().unwrap();
        if self.search {
            st.search -= 1;
            self.svc.inflight_search.set(st.search as i64);
        } else {
            st.ingest -= 1;
            self.svc.inflight_ingest.set(st.ingest as i64);
        }
    }
}

impl Service {
    /// Wrap `index` with the default admission knobs.
    pub fn new(index: Arc<StreamingIndex>) -> Service {
        Service::with_options(index, ServeConfig::default())
    }

    /// Wrap `index` with explicit admission knobs. Instruments are
    /// registered on the index's own registry (register-once: two
    /// services over one index share handles).
    pub fn with_options(index: Arc<StreamingIndex>, cfg: ServeConfig) -> Service {
        let obs = Arc::clone(index.metrics());
        Service {
            cfg,
            checkpoint_dir: None,
            permits: Mutex::new(Inflight::default()),
            search_ns: obs.histogram("service.search_ns"),
            insert_ns: obs.histogram("service.insert_ns"),
            delete_ns: obs.histogram("service.delete_ns"),
            upsert_ns: obs.histogram("service.upsert_ns"),
            control_ns: obs.histogram("service.control_ns"),
            rejected_insert: obs.counter("service.rejected_insert"),
            rejected_delete: obs.counter("service.rejected_delete"),
            rejected_upsert: obs.counter("service.rejected_upsert"),
            degraded_searches: obs.counter("service.degraded_searches"),
            search_degradation: obs.histogram("service.search_degradation"),
            inflight_search: obs.gauge("service.inflight_search"),
            inflight_ingest: obs.gauge("service.inflight_ingest"),
            index,
        }
    }

    /// Set the directory `Checkpoint` requests (and the periodic
    /// checkpoint hook) write to.
    pub fn with_checkpoint_dir(mut self, dir: Option<PathBuf>) -> Service {
        self.checkpoint_dir = dir;
        self
    }

    /// The wrapped engine, for maintenance paths (compaction driving,
    /// registry access) that are not request-shaped.
    pub fn index(&self) -> &Arc<StreamingIndex> {
        &self.index
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The directory `Checkpoint` requests write to, if configured.
    pub fn checkpoint_dir(&self) -> Option<&Path> {
        self.checkpoint_dir.as_deref()
    }

    /// Combined load pressure in [0, 1+]: the max of seal-backlog
    /// occupancy (backlog / `max_seal_backlog`) and paged-memory
    /// residency (resident / budget). 1.0 means "shed ingest".
    pub fn pressure(&self) -> f64 {
        let backlog = match self.cfg.max_seal_backlog {
            0 => 0.0,
            max => self.index.seal_backlog() as f64 / max as f64,
        };
        backlog.max(self.index.memory_pressure())
    }

    /// Serve one request. Never panics on malformed input; transport
    /// layers can forward any byte-decoded request straight in.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Search { query, topk, ef } => self.search(query, topk, ef),
            Request::Insert { vector } => self.insert(vector),
            Request::Delete { gid } => self.delete(gid),
            Request::Upsert { gid, vector } => self.upsert(gid, vector),
            Request::Flush => self.control(|idx| {
                idx.flush();
                Response::Flushed
            }),
            Request::Stats => self.control(|idx| Response::Stats(idx.stats())),
            Request::MetricsSnapshot => self.control(|idx| Response::Metrics {
                json: idx.metrics_snapshot().to_json().to_pretty(),
            }),
            Request::Checkpoint => self.checkpoint(),
        }
    }

    // ------------------------------------------------------- searches

    fn search(&self, query: Vec<f32>, topk: usize, ef: usize) -> Response {
        if query.len() != self.index.dim() {
            return Response::Error {
                message: format!(
                    "query dimension {} != index dimension {}",
                    query.len(),
                    self.index.dim()
                ),
            };
        }
        // Searches are always admitted; over-commit only degrades —
        // and degrades *proportionally*: one request past the limit is
        // a 1/max nudge, not an instant collapse to `ef = topk` (the
        // old cliff cost a full recall tier for a single extra
        // in-flight search at zero pressure).
        let over_frac = {
            let mut st = self.permits.lock().unwrap();
            st.search += 1;
            self.inflight_search.set(st.search as i64);
            let max = self.cfg.max_inflight_search;
            if st.search > max {
                ((st.search - max) as f64 / max.max(1) as f64).clamp(0.0, 1.0)
            } else {
                0.0
            }
        };
        let permit = Permit {
            svc: self,
            search: true,
        };
        let requested = if ef == 0 { self.index.default_ef() } else { ef }.max(topk);
        let pressure_frac = ((self.pressure() - 0.5) / 0.5).clamp(0.0, 1.0);
        let frac = over_frac.max(pressure_frac);
        let ef_eff = requested - ((requested - topk) as f64 * frac).round() as usize;
        let degraded = ef_eff < requested;
        if degraded {
            self.degraded_searches.inc();
            // Magnitude in per-mille of the requested→topk span: 1000
            // means the beam fully collapsed to `topk`.
            self.search_degradation.record_ns((frac * 1000.0).round() as u64);
        }
        let t = Instant::now();
        let hits = self.index.search_ef(&query, topk, ef_eff);
        self.search_ns.record_duration(t.elapsed());
        drop(permit);
        Response::Hits { hits, degraded }
    }

    // --------------------------------------------------------- ingest

    /// Admit one ingest operation or explain the rejection.
    fn ingest_permit(&self, class: RequestClass) -> Result<Permit<'_>, Response> {
        let shed = self.pressure() >= 1.0;
        let admitted = {
            let mut st = self.permits.lock().unwrap();
            if shed || st.ingest >= self.cfg.max_inflight_ingest {
                false
            } else {
                st.ingest += 1;
                self.inflight_ingest.set(st.ingest as i64);
                true
            }
        };
        if admitted {
            return Ok(Permit {
                svc: self,
                search: false,
            });
        }
        match class {
            RequestClass::Insert => self.rejected_insert.inc(),
            RequestClass::Delete => self.rejected_delete.inc(),
            RequestClass::Upsert => self.rejected_upsert.inc(),
            _ => {}
        }
        Err(Response::Overloaded {
            class,
            retry_after_ms: self.cfg.retry_after_ms,
        })
    }

    fn insert(&self, vector: Vec<f32>) -> Response {
        if vector.len() != self.index.dim() {
            return Response::Error {
                message: format!(
                    "insert dimension {} != index dimension {}",
                    vector.len(),
                    self.index.dim()
                ),
            };
        }
        let permit = match self.ingest_permit(RequestClass::Insert) {
            Ok(p) => p,
            Err(r) => return r,
        };
        let t = Instant::now();
        let gid = self.index.insert(&vector);
        self.insert_ns.record_duration(t.elapsed());
        drop(permit);
        Response::Inserted { gid }
    }

    fn delete(&self, gid: u32) -> Response {
        let permit = match self.ingest_permit(RequestClass::Delete) {
            Ok(p) => p,
            Err(r) => return r,
        };
        let t = Instant::now();
        let existed = self.index.delete(gid);
        self.delete_ns.record_duration(t.elapsed());
        drop(permit);
        Response::Deleted { existed }
    }

    fn upsert(&self, gid: u32, vector: Vec<f32>) -> Response {
        if vector.len() != self.index.dim() {
            return Response::Error {
                message: format!(
                    "upsert dimension {} != index dimension {}",
                    vector.len(),
                    self.index.dim()
                ),
            };
        }
        let permit = match self.ingest_permit(RequestClass::Upsert) {
            Ok(p) => p,
            Err(r) => return r,
        };
        let t = Instant::now();
        let applied = self.index.upsert(gid, &vector);
        self.upsert_ns.record_duration(t.elapsed());
        drop(permit);
        Response::Upserted { applied }
    }

    // -------------------------------------------------------- control

    fn control(&self, op: impl FnOnce(&StreamingIndex) -> Response) -> Response {
        let t = Instant::now();
        let resp = op(&self.index);
        self.control_ns.record_duration(t.elapsed());
        resp
    }

    fn checkpoint(&self) -> Response {
        let Some(dir) = self.checkpoint_dir.clone() else {
            return Response::Error {
                message: "no checkpoint directory configured".to_string(),
            };
        };
        self.control(|idx| match idx.checkpoint(&dir) {
            Ok(st) => Response::Checkpointed {
                segments: st.segments as u64,
                files_written: st.segment_files_written as u64,
                files_reused: st.segment_files_reused as u64,
                gc_removed: st.gc_removed as u64,
                memtable_rows: st.memtable_rows as u64,
                manifest_bytes: st.manifest_bytes,
            },
            Err(e) => Response::Error {
                message: format!("checkpoint failed: {e:#}"),
            },
        })
    }
}

// ------------------------------------------------------------ metrics

/// Atomically write `index`'s metrics snapshot as pretty JSON (temp
/// file + rename, so a reader never sees a half-written dump).
pub fn write_metrics(index: &StreamingIndex, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create metrics dir {parent:?}"))?;
        }
    }
    let json = index.metrics_snapshot().to_json();
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, json.to_pretty()).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Background `--metrics-interval` dumper with a real shutdown: the
/// channel closes (or receives a stop signal) and the thread is
/// *joined*, in every exit path — RAII, so the early-return leak the
/// old ad-hoc thread had cannot recur.
pub struct MetricsDumper {
    tx: Option<mpsc::Sender<()>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsDumper {
    /// Rewrite `path` every `interval` until stopped/dropped.
    /// Snapshots are a few lock-free loads per instrument; a mid-run
    /// dump never perturbs the run it observes.
    pub fn spawn(index: Arc<StreamingIndex>, path: PathBuf, interval: Duration) -> MetricsDumper {
        let (tx, rx) = mpsc::channel::<()>();
        let join = std::thread::spawn(move || loop {
            match rx.recv_timeout(interval) {
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if let Err(e) = write_metrics(&index, &path) {
                        eprintln!("metrics dump failed: {e:#}");
                    }
                }
                // Stop signal or sender dropped: shut down.
                _ => break,
            }
        });
        MetricsDumper {
            tx: Some(tx),
            join: Some(join),
        }
    }

    /// Stop and join the dumper thread (also done on drop).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // Dropping the sender closes the channel, which wakes
        // `recv_timeout` immediately — no park/unpark race window.
        self.tx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for MetricsDumper {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StreamConfig;
    use crate::distance::Metric;

    fn tiny_service(cfg: ServeConfig) -> Service {
        let index = Arc::new(StreamingIndex::new(
            4,
            Metric::L2,
            StreamConfig {
                segment_size: 16,
                ..Default::default()
            },
        ));
        Service::with_options(index, cfg)
    }

    fn vec4(x: f32) -> Vec<f32> {
        vec![x, x + 1.0, x + 2.0, x + 3.0]
    }

    #[test]
    fn basic_request_lifecycle() {
        let svc = tiny_service(ServeConfig::default());
        let gid = match svc.handle(Request::Insert { vector: vec4(1.0) }) {
            Response::Inserted { gid } => gid,
            other => panic!("unexpected: {other:?}"),
        };
        match svc.handle(Request::Search {
            query: vec4(1.0),
            topk: 1,
            ef: 0,
        }) {
            Response::Hits { hits, degraded } => {
                assert_eq!(hits[0].1, gid);
                assert!(!degraded);
            }
            other => panic!("unexpected: {other:?}"),
        }
        match svc.handle(Request::Upsert {
            gid,
            vector: vec4(2.0),
        }) {
            Response::Upserted { applied } => assert!(applied),
            other => panic!("unexpected: {other:?}"),
        }
        let st = match svc.handle(Request::Stats) {
            Response::Stats(st) => st,
            other => panic!("unexpected: {other:?}"),
        };
        assert_eq!(st.upserts, 1);
        match svc.handle(Request::Flush) {
            Response::Flushed => {}
            other => panic!("unexpected: {other:?}"),
        }
        match svc.handle(Request::MetricsSnapshot) {
            Response::Metrics { json } => {
                let parsed = crate::util::json::Json::parse(&json).unwrap();
                assert_eq!(parsed.get("version").unwrap().as_f64(), Some(1.0));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn dimension_mismatch_is_an_error_not_a_panic() {
        let svc = tiny_service(ServeConfig::default());
        for req in [
            Request::Search {
                query: vec![1.0; 3],
                topk: 1,
                ef: 0,
            },
            Request::Insert {
                vector: vec![1.0; 5],
            },
            Request::Upsert {
                gid: 0,
                vector: vec![],
            },
        ] {
            match svc.handle(req) {
                Response::Error { message } => assert!(message.contains("dimension")),
                other => panic!("unexpected: {other:?}"),
            }
        }
    }

    #[test]
    fn zero_ingest_permits_reject_every_mutation_but_never_searches() {
        let svc = tiny_service(ServeConfig {
            max_inflight_ingest: 0,
            retry_after_ms: 9,
            ..ServeConfig::default()
        });
        match svc.handle(Request::Insert { vector: vec4(0.0) }) {
            Response::Overloaded {
                class,
                retry_after_ms,
            } => {
                assert_eq!(class, RequestClass::Insert);
                assert_eq!(retry_after_ms, 9);
            }
            other => panic!("unexpected: {other:?}"),
        }
        match svc.handle(Request::Delete { gid: 0 }) {
            Response::Overloaded { class, .. } => assert_eq!(class, RequestClass::Delete),
            other => panic!("unexpected: {other:?}"),
        }
        match svc.handle(Request::Upsert {
            gid: 0,
            vector: vec4(0.0),
        }) {
            Response::Overloaded { class, .. } => assert_eq!(class, RequestClass::Upsert),
            other => panic!("unexpected: {other:?}"),
        }
        // Searches still answer (empty index -> empty hits, no error).
        match svc.handle(Request::Search {
            query: vec4(0.0),
            topk: 3,
            ef: 8,
        }) {
            Response::Hits { hits, .. } => assert!(hits.is_empty()),
            other => panic!("unexpected: {other:?}"),
        }
        let obs = svc.index().metrics();
        assert_eq!(obs.counter("service.rejected_insert").get(), 1);
        assert_eq!(obs.counter("service.rejected_delete").get(), 1);
        assert_eq!(obs.counter("service.rejected_upsert").get(), 1);
    }

    #[test]
    fn overcommitted_search_class_degrades_to_topk_beam() {
        let svc = tiny_service(ServeConfig {
            max_inflight_search: 0,
            ..ServeConfig::default()
        });
        for i in 0..8 {
            match svc.handle(Request::Insert {
                vector: vec4(i as f32),
            }) {
                Response::Inserted { .. } => {}
                other => panic!("unexpected: {other:?}"),
            }
        }
        match svc.handle(Request::Search {
            query: vec4(3.0),
            topk: 2,
            ef: 64,
        }) {
            Response::Hits { hits, degraded } => {
                assert!(degraded, "inflight 1 > max 0 must degrade");
                assert!(!hits.is_empty());
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(
            svc.index().metrics().counter("service.degraded_searches").get(),
            1
        );
        // max_inflight_search == 0 is the degenerate limit: a single
        // in-flight search is a full over-commit, so the magnitude
        // histogram records the whole requested→topk span (1000‰).
        let h = svc.index().metrics().histogram("service.search_degradation").snapshot();
        assert_eq!(h.count, 1);
        assert_eq!(h.max_ns, 1000);
    }

    #[test]
    fn one_extra_search_degrades_proportionally_not_to_the_topk_cliff() {
        let svc = tiny_service(ServeConfig {
            max_inflight_search: 4,
            ..ServeConfig::default()
        });
        for i in 0..8 {
            match svc.handle(Request::Insert {
                vector: vec4(i as f32),
            }) {
                Response::Inserted { .. } => {}
                other => panic!("unexpected: {other:?}"),
            }
        }
        // Pretend four searches are already in flight; the next one is
        // the fifth — over the limit by exactly one.
        svc.permits.lock().unwrap().search = 4;
        match svc.handle(Request::Search {
            query: vec4(3.0),
            topk: 2,
            ef: 66,
        }) {
            Response::Hits { hits, degraded } => {
                assert!(degraded, "over by one must still mark degraded");
                assert!(!hits.is_empty());
            }
            other => panic!("unexpected: {other:?}"),
        }
        // Over by 1 of 4 → frac 0.25 → 250‰, nowhere near the old
        // straight-to-1000 cliff.
        let h = svc.index().metrics().histogram("service.search_degradation").snapshot();
        assert_eq!(h.count, 1);
        assert_eq!(h.max_ns, 250);
        // The permit of the real search released; the phantoms remain.
        assert_eq!(svc.permits.lock().unwrap().search, 4);
    }

    #[test]
    fn retry_overloaded_surfaces_a_typed_error_when_saturation_never_clears() {
        let mut calls = 0u32;
        let err = retry_overloaded(3, || {
            calls += 1;
            Response::Overloaded {
                class: RequestClass::Upsert,
                retry_after_ms: 0,
            }
        })
        .unwrap_err();
        assert_eq!(calls, 3, "exactly the budgeted attempts, then stop");
        assert_eq!(
            err,
            RetriesExhausted {
                class: RequestClass::Upsert,
                attempts: 3
            }
        );
        assert!(err.to_string().contains("upsert"));
        // A success inside the budget passes straight through.
        match retry_overloaded(3, || Response::Flushed).unwrap() {
            Response::Flushed => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn checkpoint_without_dir_is_a_clean_error() {
        let svc = tiny_service(ServeConfig::default());
        match svc.handle(Request::Checkpoint) {
            Response::Error { message } => assert!(message.contains("checkpoint")),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn metrics_dumper_joins_on_drop() {
        let dir = std::env::temp_dir().join(format!(
            "knnmerge-dumper-{}",
            crate::util::unique_scratch_suffix()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let svc = tiny_service(ServeConfig::default());
        let path = dir.join("metrics.json");
        let dumper = MetricsDumper::spawn(
            Arc::clone(svc.index()),
            path.clone(),
            Duration::from_millis(5),
        );
        std::thread::sleep(Duration::from_millis(40));
        dumper.stop(); // joins: after this no thread is writing
        assert!(path.exists(), "periodic dump ran");
        std::fs::remove_dir_all(&dir).ok();
    }
}

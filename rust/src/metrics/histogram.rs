//! Log-bucketed latency histogram (HDR-lite).
//!
//! Values are `u64` nanoseconds bucketed into 16 linear sub-buckets per
//! power-of-two octave, which bounds the relative quantile error at one
//! sub-bucket width (≤ 1/16 ≈ 6.25%) while covering the full `u64`
//! range in a fixed 976-slot table (~8 KB of atomics). Recording is one
//! relaxed `fetch_add` on the bucket plus a relaxed `fetch_max` for the
//! exact maximum — no locks, no allocation — so the stream engine can
//! afford to time *every* `insert`/`search_ef`/`delete`/`upsert` call.
//!
//! Quantiles are answered from a [`HistogramSnapshot`]: one pass copies
//! the bucket counts, and every quantile is then derived from that one
//! frozen copy, so p50/p95/p99 reported together always describe the
//! same set of samples (snapshot-consistent) even while recorders keep
//! running. Snapshots (and live histograms) merge by bucket-wise
//! addition, which is exactly equivalent to having recorded both sample
//! streams into one histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Linear sub-bucket resolution: 2^4 = 16 sub-buckets per octave.
pub const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS;
/// Octaves above the linear range: exponents `SUB_BITS..=63`.
const OCTAVES: usize = 64 - SUB_BITS as usize;
/// Total buckets: the linear `[0, 16)` range plus 16 per octave.
const BUCKETS: usize = OCTAVES * SUB + SUB;

/// Bucket index for a value. Values below `SUB` map to themselves
/// (exact); above, the top `SUB_BITS` bits after the leading one select
/// the sub-bucket within the value's octave. The mapping is monotone
/// and contiguous across the linear/log boundary (15 → 15, 16 → 16).
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let e = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (e - SUB_BITS as usize)) & (SUB as u64 - 1)) as usize;
        (e - SUB_BITS as usize) * SUB + SUB + sub
    }
}

/// Lowest value mapping to `idx` (inverse of [`bucket_index`]).
#[inline]
fn bucket_low(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let t = idx - SUB;
        let e = t / SUB + SUB_BITS as usize;
        let sub = (t % SUB) as u64;
        (SUB as u64 + sub) << (e - SUB_BITS as usize)
    }
}

/// Width of bucket `idx` (1 in the linear range, 2^(e-SUB_BITS) above).
#[inline]
fn bucket_width(idx: usize) -> u64 {
    if idx < SUB {
        1
    } else {
        1u64 << ((idx - SUB) / SUB)
    }
}

/// Lock-free log-bucketed histogram of `u64` values (nanoseconds by
/// convention; [`Histogram::record_secs`] converts).
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets: Vec<AtomicU64> = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets: buckets.into_boxed_slice(),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value. Hot path: one relaxed add + one relaxed max.
    #[inline]
    pub fn record_ns(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Record seconds (converted to nanoseconds; negatives clamp to 0).
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        self.record_ns((secs * 1e9) as u64);
    }

    /// Total samples recorded so far (one pass over the buckets).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Add every sample of `other` into `self` (bucket-wise; identical
    /// to having recorded `other`'s stream here).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Freeze the current contents. All quantiles derived from the
    /// returned snapshot describe the same frozen sample set.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistogramSnapshot {
            counts,
            count,
            max_ns: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Frozen histogram contents; quantiles, mean, merge, and JSON export.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    counts: Vec<u64>,
    /// Total samples in this snapshot.
    pub count: u64,
    /// Exact maximum recorded value.
    pub max_ns: u64,
}

impl HistogramSnapshot {
    /// An empty snapshot (zero samples).
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot {
            counts: vec![0; BUCKETS],
            count: 0,
            max_ns: 0,
        }
    }

    /// The q-quantile (q in [0, 1]) as nanoseconds: the upper edge of
    /// the bucket holding the sample of rank `ceil(q · count)`, clamped
    /// to the exact max. Guaranteed `exact ≤ result ≤ exact · 17/16`.
    /// Returns 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let upper = bucket_low(idx) + (bucket_width(idx) - 1);
                return upper.min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// The q-quantile in seconds.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 / 1e9
    }

    /// Approximate mean (bucket midpoints), in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(idx, &c)| {
                let mid = bucket_low(idx) as f64 + (bucket_width(idx) - 1) as f64 / 2.0;
                mid * c as f64
            })
            .sum();
        sum / self.count as f64
    }

    /// Combine two snapshots (bucket-wise sum; max of maxes).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .zip(other.counts.iter())
            .map(|(a, b)| a + b)
            .collect();
        let count = self.count + other.count;
        HistogramSnapshot {
            counts,
            count,
            max_ns: self.max_ns.max(other.max_ns),
        }
    }

    /// JSON form used inside [`crate::metrics::MetricsSnapshot`]:
    /// `{count, max_ns, mean_ns, p50_ns, p95_ns, p99_ns, p999_ns}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count);
        o.set("max_ns", self.max_ns);
        o.set("mean_ns", self.mean_ns());
        o.set("p50_ns", self.quantile_ns(0.50));
        o.set("p95_ns", self.quantile_ns(0.95));
        o.set("p99_ns", self.quantile_ns(0.99));
        o.set("p999_ns", self.quantile_ns(0.999));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_contiguous() {
        // Exact in the linear range, continuous across the boundary.
        for v in 0..16u64 {
            assert_eq!(bucket_index(v), v as usize);
        }
        assert_eq!(bucket_index(16), 16);
        let mut probes: Vec<u64> = Vec::new();
        for shift in 0..60 {
            probes.extend([1u64 << shift, (1u64 << shift) + 1, (2u64 << shift) - 1]);
        }
        probes.sort_unstable();
        let mut prev = 0;
        for v in probes {
            let idx = bucket_index(v);
            assert!(idx >= prev, "monotone broke at v={v}");
            assert!(idx < BUCKETS);
            // The inverse brackets the value.
            let low = bucket_low(idx);
            let width = bucket_width(idx);
            assert!(low <= v && v - low < width, "v={v} idx={idx} low={low} w={width}");
            prev = idx;
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let h = Histogram::new();
        for v in 1..=10u64 {
            h.record_ns(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        assert_eq!(s.max_ns, 10);
        assert_eq!(s.quantile_ns(0.5), 5);
        assert_eq!(s.quantile_ns(1.0), 10);
        assert_eq!(s.quantile_ns(0.0), 1); // rank clamps to 1
    }

    #[test]
    fn quantile_error_is_bounded_by_sub_bucket_width() {
        let h = Histogram::new();
        let vals: Vec<u64> = (0..1000u64).map(|i| i * i * 37 + 5).collect();
        for &v in &vals {
            h.record_ns(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        let s = h.snapshot();
        for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let exact = sorted[rank - 1];
            let est = s.quantile_ns(q);
            assert!(est >= exact, "q={q}: est {est} < exact {exact}");
            assert!(est - exact <= exact / 16 + 1, "q={q}: est {est} exact {exact}");
        }
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for i in 0..500u64 {
            let v = i * 977 + 3;
            if i % 2 == 0 { a.record_ns(v) } else { b.record_ns(v) }
            all.record_ns(v);
        }
        a.merge_from(&b);
        let (sa, sall) = (a.snapshot(), all.snapshot());
        assert_eq!(sa.count, sall.count);
        assert_eq!(sa.max_ns, sall.max_ns);
        for q in [0.1, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(sa.quantile_ns(q), sall.quantile_ns(q), "q={q}");
        }
        // Snapshot-level merge agrees too.
        let (s1, s2) = (Histogram::new(), Histogram::new());
        s1.record_ns(10);
        s2.record_ns(1_000_000);
        let merged = s1.snapshot().merge(&s2.snapshot());
        assert_eq!(merged.count, 2);
        assert_eq!(merged.max_ns, 1_000_000);
    }

    #[test]
    fn json_has_all_quantile_fields() {
        let h = Histogram::new();
        h.record_secs(0.001);
        let j = h.snapshot().to_json();
        for key in ["count", "max_ns", "mean_ns", "p50_ns", "p95_ns", "p99_ns", "p999_ns"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("count").unwrap().as_f64(), Some(1.0));
    }
}

//! Named metrics registry: counters, gauges, histograms, span stats,
//! and the event journal behind one `snapshot()` with a versioned JSON
//! form.
//!
//! Registration (`counter("stream.inserted")`) is a mutex + BTreeMap
//! lookup returning a shared [`Counter`] handle; callers register once
//! and cache the `Arc`, so the hot path is a single relaxed atomic op
//! with no lock and no allocation. A [`Registry`] is cheap enough to
//! make per-component (each `StreamingIndex` owns one, keeping
//! concurrent tests independent); [`Registry::global`] serves code
//! without a natural owner (out-of-core builds, the cluster driver).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::events::{EventJournal, EventRecord, DEFAULT_JOURNAL_CAP};
use super::histogram::{Histogram, HistogramSnapshot};
use super::span::SpanStats;
use super::Phase;
use crate::util::json::Json;

/// Monotone event counter (relaxed atomics).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value. Restore paths only (resuming counts from a
    /// checkpoint manifest); live accounting must use `inc`/`add`.
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    /// Raise to at least `n` (high-water marks).
    #[inline]
    pub fn fetch_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }
}

/// Point-in-time signed value (resident bytes, queue depths, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The named-instrument registry. See the module docs for the
/// register-once / record-lock-free contract.
#[derive(Debug)]
pub struct Registry {
    start: Instant,
    // The four map locks are terminal: registration/snapshot takes
    // them one at a time (never nested) and hot paths go through the
    // returned `Arc`s, so they may be taken while holding any engine
    // lock but must never wrap another acquisition.
    // LOCK-ORDER: metrics.registry.counters terminal
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    // LOCK-ORDER: metrics.registry.gauges terminal
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    // LOCK-ORDER: metrics.registry.histograms terminal
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    // LOCK-ORDER: metrics.registry.spans terminal
    spans: Mutex<BTreeMap<String, Arc<SpanStats>>>,
    journal: EventJournal,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            start: Instant::now(),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(BTreeMap::new()),
            journal: EventJournal::new(DEFAULT_JOURNAL_CAP),
        }
    }

    /// The process-global registry, for call sites without a natural
    /// owning component (out-of-core coordinator, cluster driver).
    pub fn global() -> Arc<Registry> {
        // LOCK-ORDER: metrics.global terminal
        static GLOBAL: Mutex<Option<Arc<Registry>>> = Mutex::new(None);
        GLOBAL
            .lock()
            .unwrap()
            .get_or_insert_with(|| Arc::new(Registry::new()))
            .clone()
    }

    /// Register-or-get a counter by name.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Register-or-get a gauge by name.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Register-or-get a histogram by name.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Register-or-get span stats by name. The phase label of the first
    /// registration wins; spans of one name must share a phase.
    pub fn span_stats(&self, name: &str, phase: Phase) -> Arc<SpanStats> {
        let mut map = self.spans.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(SpanStats::new(phase))),
        )
    }

    /// Append an event to the journal.
    pub fn event(&self, kind: &str, fields: &[(&str, f64)]) {
        self.journal.push(kind, fields);
    }

    pub fn journal(&self) -> &EventJournal {
        &self.journal
    }

    /// Freeze everything into one coherent report.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), g.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.snapshot()))
            .collect();
        let spans = self
            .spans
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    SpanSnapshot {
                        phase: s.phase.name(),
                        count: s.count.get(),
                        self_ns: s.self_ns.get(),
                    },
                )
            })
            .collect();
        MetricsSnapshot {
            version: SNAPSHOT_VERSION,
            uptime_s: self.start.elapsed().as_secs_f64(),
            counters,
            gauges,
            histograms,
            spans,
            events: self.journal.snapshot(),
        }
    }
}

/// Schema version of [`MetricsSnapshot::to_json`]. Bump on any
/// breaking change to key names or nesting.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Frozen totals of one registry's span stats.
#[derive(Clone, Copy, Debug)]
pub struct SpanSnapshot {
    pub phase: &'static str,
    pub count: u64,
    /// Nanoseconds billed to this span itself (child spans excluded).
    pub self_ns: u64,
}

/// One coherent metrics report: every instrument of a registry, frozen
/// together, with a versioned JSON form for `--metrics-out` dumps.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub version: u32,
    pub uptime_s: f64,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
    pub spans: BTreeMap<String, SpanSnapshot>,
    pub events: Vec<EventRecord>,
}

impl MetricsSnapshot {
    /// Versioned JSON export (validated by
    /// `scripts/check_metrics_snapshot.py` in the verify.sh smoke):
    ///
    /// ```json
    /// {
    ///   "version": 1,
    ///   "uptime_s": 12.3,
    ///   "counters": {"stream.inserted": 10000},
    ///   "gauges": {"budget.resident_bytes": 0},
    ///   "histograms": {"stream.insert_ns": {"count": 10000, "p50_ns": 900, ...}},
    ///   "spans": {"seal_build": {"phase": "build", "count": 4, "self_ns": 1}},
    ///   "events": [{"t_s": 0.5, "kind": "seal_published", "fields": {...}}]
    /// }
    /// ```
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, *v);
        }
        let mut histograms = Json::obj();
        for (k, h) in &self.histograms {
            histograms.set(k, h.to_json());
        }
        let mut spans = Json::obj();
        for (k, s) in &self.spans {
            let mut span = Json::obj();
            span.set("phase", s.phase);
            span.set("count", s.count);
            span.set("self_ns", s.self_ns);
            spans.set(k, span);
        }
        let events: Vec<Json> = self.events.iter().map(|e| e.to_json()).collect();
        let mut o = Json::obj();
        o.set("version", self.version as u64);
        o.set("uptime_s", self.uptime_s);
        o.set("counters", counters);
        o.set("gauges", gauges);
        o.set("histograms", histograms);
        o.set("spans", spans);
        o.set("events", Json::Arr(events));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_by_name() {
        let reg = Registry::new();
        let a = reg.counter("x");
        let b = reg.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("x").get(), 3);
        let g = reg.gauge("depth");
        g.set(5);
        g.add(-2);
        assert_eq!(reg.gauge("depth").get(), 3);
        let h = reg.histogram("lat");
        h.record_ns(100);
        assert_eq!(reg.histogram("lat").count(), 1);
    }

    #[test]
    fn snapshot_to_json_roundtrips_through_parser() {
        let reg = Registry::new();
        reg.counter("c.one").add(7);
        reg.gauge("g.depth").set(-3);
        reg.histogram("h.lat").record_ns(1500);
        reg.event("tick", &[("n", 1.0)]);
        let json = reg.snapshot().to_json();
        let back = Json::parse(&json.to_pretty()).unwrap();
        assert_eq!(back.get("version").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            back.get("counters").unwrap().get("c.one").unwrap().as_f64(),
            Some(7.0)
        );
        assert_eq!(
            back.get("gauges").unwrap().get("g.depth").unwrap().as_f64(),
            Some(-3.0)
        );
        let hist = back.get("histograms").unwrap().get("h.lat").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(1.0));
        assert!(hist.get("p99_ns").unwrap().as_f64().unwrap() >= 1000.0);
        let events = back.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").unwrap().as_str(), Some("tick"));
    }

    #[test]
    fn global_registry_is_one_instance() {
        let a = Registry::global();
        let b = Registry::global();
        assert!(Arc::ptr_eq(&a, &b));
    }
}

//! RAII span guards: time a scope, label it with a [`Phase`], and
//! attribute nested time correctly.
//!
//! `let _span = Span::enter(&reg, "seal_build", Phase::Build);` times
//! the enclosing scope; the guard's `Drop` records the elapsed time
//! into the registry's per-name [`SpanStats`]. Nesting is handled with
//! a thread-local stack of child-time accumulators: a child span's
//! full elapsed time is subtracted from its parent, so each phase is
//! billed *self time only* and per-phase totals add up instead of
//! double-counting. [`Span::enter_billed`] additionally feeds the self
//! time into a [`CostLedger`] phase, bridging span timing into the
//! paper's Fig. 14 cost breakdown.
//!
//! Guards are `!Send`: the child-time stack is thread-local, so a guard
//! must drop on the thread that created it (ordinary scoped RAII usage
//! guarantees this; `scripts/static_check.py` rejects call sites that
//! discard the guard).

use std::cell::RefCell;
use std::marker::PhantomData;
use std::sync::Arc;
use std::time::Instant;

use super::registry::{Counter, Registry};
use super::{CostLedger, Phase};

/// Accumulated totals for one span name: how many times it ran and the
/// self-time (nanoseconds, child spans excluded) it consumed.
#[derive(Debug)]
pub struct SpanStats {
    pub phase: Phase,
    pub count: Counter,
    pub self_ns: Counter,
}

impl SpanStats {
    pub fn new(phase: Phase) -> SpanStats {
        SpanStats {
            phase,
            count: Counter::new(),
            self_ns: Counter::new(),
        }
    }
}

thread_local! {
    /// One child-nanoseconds accumulator per live span on this thread.
    static CHILD_NS: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

/// Entry points for span timing; see the module docs.
pub struct Span;

impl Span {
    /// Start a span. Bind the guard (`let _span = ...`); its `Drop`
    /// records the scope's time.
    pub fn enter(registry: &Registry, name: &str, phase: Phase) -> SpanGuard<'static> {
        Span::enter_impl(registry, name, phase, None)
    }

    /// Start a span that also bills its *self* time (children excluded)
    /// to `ledger`'s matching phase on drop.
    pub fn enter_billed<'l>(
        registry: &Registry,
        name: &str,
        phase: Phase,
        ledger: &'l CostLedger,
    ) -> SpanGuard<'l> {
        Span::enter_impl(registry, name, phase, Some(ledger))
    }

    fn enter_impl<'l>(
        registry: &Registry,
        name: &str,
        phase: Phase,
        ledger: Option<&'l CostLedger>,
    ) -> SpanGuard<'l> {
        let stats = registry.span_stats(name, phase);
        CHILD_NS.with(|stack| stack.borrow_mut().push(0));
        SpanGuard {
            stats,
            ledger,
            start: Instant::now(),
            _not_send: PhantomData,
        }
    }
}

/// Live span; records on drop. Must drop on its creating thread.
pub struct SpanGuard<'l> {
    stats: Arc<SpanStats>,
    ledger: Option<&'l CostLedger>,
    start: Instant,
    /// `*const ()` makes the guard `!Send`: the child-time stack is
    /// thread-local.
    _not_send: PhantomData<*const ()>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let total_ns = self.start.elapsed().as_nanos() as u64;
        let child_ns = CHILD_NS.with(|stack| {
            let mut stack = stack.borrow_mut();
            let child = stack.pop().unwrap_or(0);
            // Our full elapsed time is the parent's child time.
            if let Some(parent) = stack.last_mut() {
                *parent += total_ns;
            }
            child
        });
        let self_ns = total_ns.saturating_sub(child_ns);
        self.stats.count.inc();
        self.stats.self_ns.add(self_ns);
        if let Some(ledger) = self.ledger {
            ledger.add(self.stats.phase, self_ns as f64 / 1e9);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn span_records_count_and_time() {
        let reg = Registry::new();
        {
            let _span = Span::enter(&reg, "work", Phase::Other);
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = reg.span_stats("work", Phase::Other);
        assert_eq!(stats.count.get(), 1);
        assert!(stats.self_ns.get() >= 4_000_000, "{}", stats.self_ns.get());
    }

    #[test]
    fn nested_span_time_bills_child_only_once() {
        let reg = Registry::new();
        let ledger = CostLedger::new();
        {
            let _parent = Span::enter_billed(&reg, "parent", Phase::Merge, &ledger);
            std::thread::sleep(Duration::from_millis(10));
            {
                let _child = Span::enter_billed(&reg, "child", Phase::Build, &ledger);
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        // Child gets its full sleep; parent keeps only its own work.
        assert!(ledger.secs(Phase::Build) >= 0.020, "{}", ledger.secs(Phase::Build));
        assert!(ledger.secs(Phase::Merge) >= 0.008, "{}", ledger.secs(Phase::Merge));
        assert!(
            ledger.secs(Phase::Merge) < ledger.secs(Phase::Build),
            "parent self time must exclude the child's 25ms: merge={} build={}",
            ledger.secs(Phase::Merge),
            ledger.secs(Phase::Build)
        );
        let parent = reg.span_stats("parent", Phase::Merge);
        let child = reg.span_stats("child", Phase::Build);
        assert!(parent.self_ns.get() < child.self_ns.get());
    }

    #[test]
    fn sibling_spans_do_not_inherit_each_other() {
        let reg = Registry::new();
        for _ in 0..2 {
            let _a = Span::enter(&reg, "a", Phase::Other);
        }
        let stats = reg.span_stats("a", Phase::Other);
        assert_eq!(stats.count.get(), 2);
        // Both were root spans: no stack frame left behind.
        CHILD_NS.with(|s| assert!(s.borrow().is_empty()));
    }
}

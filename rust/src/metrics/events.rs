//! Bounded ring-buffer event journal for postmortems.
//!
//! Counters tell you *how much*; the journal tells you *what happened
//! last*: seal publications, compaction in→out sizes, checkpoint
//! generations, budget eviction pressure. Pushing is a short mutex
//! section on a fixed-capacity `VecDeque` — events fire at background
//! cadence (seals, compactions), never per-operation, so this is off
//! the hot path by construction. When full, the oldest event is
//! dropped: a snapshot always holds the *last* `cap` events.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Default journal capacity (events retained).
pub const DEFAULT_JOURNAL_CAP: usize = 256;

/// One journal entry: seconds since the journal was created, an event
/// kind, and a small set of numeric fields.
#[derive(Clone, Debug)]
pub struct EventRecord {
    pub t_s: f64,
    pub kind: String,
    pub fields: Vec<(String, f64)>,
}

impl EventRecord {
    /// `{t_s, kind, fields: {name: value, ...}}`.
    pub fn to_json(&self) -> Json {
        let mut fields = Json::obj();
        for (k, v) in &self.fields {
            fields.set(k, *v);
        }
        let mut o = Json::obj();
        o.set("t_s", self.t_s);
        o.set("kind", self.kind.as_str());
        o.set("fields", fields);
        o
    }
}

/// Fixed-capacity, oldest-out event ring.
#[derive(Debug)]
pub struct EventJournal {
    start: Instant,
    cap: usize,
    // LOCK-ORDER: metrics.events.ring terminal
    ring: Mutex<VecDeque<EventRecord>>,
}

impl Default for EventJournal {
    fn default() -> EventJournal {
        EventJournal::new(DEFAULT_JOURNAL_CAP)
    }
}

impl EventJournal {
    pub fn new(cap: usize) -> EventJournal {
        EventJournal {
            start: Instant::now(),
            cap: cap.max(1),
            ring: Mutex::new(VecDeque::with_capacity(cap.max(1))),
        }
    }

    /// Append an event, evicting the oldest when at capacity.
    pub fn push(&self, kind: &str, fields: &[(&str, f64)]) {
        let rec = EventRecord {
            t_s: self.start.elapsed().as_secs_f64(),
            kind: kind.to_string(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    /// The retained events, oldest first.
    pub fn snapshot(&self) -> Vec<EventRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_only_the_last_cap_events() {
        let j = EventJournal::new(3);
        for i in 0..5u64 {
            j.push("tick", &[("i", i as f64)]);
        }
        let evs = j.snapshot();
        assert_eq!(evs.len(), 3);
        let seen: Vec<f64> = evs.iter().map(|e| e.fields[0].1).collect();
        assert_eq!(seen, vec![2.0, 3.0, 4.0], "oldest dropped first");
        // Timestamps are monotone non-decreasing.
        assert!(evs.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn event_json_shape() {
        let j = EventJournal::new(8);
        j.push("seal_published", &[("segment", 3.0), ("rows", 100.0)]);
        let ev = &j.snapshot()[0];
        let json = ev.to_json();
        assert_eq!(json.get("kind").unwrap().as_str(), Some("seal_published"));
        let fields = json.get("fields").unwrap();
        assert_eq!(fields.get("rows").unwrap().as_f64(), Some(100.0));
        assert!(json.get("t_s").unwrap().as_f64().unwrap() >= 0.0);
    }
}

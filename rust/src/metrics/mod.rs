//! Phase-labelled cost accounting.
//!
//! The distributed procedure reports *where* time goes (paper Fig. 14:
//! subgraph construction vs merge compute vs data exchange vs storage
//! access). [`CostLedger`] accumulates seconds per [`Phase`], mixing
//! measured wall-clock (compute) and modelled time (network/storage,
//! derived from byte counts and the configured bandwidths).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Cost categories (Fig. 14's breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Subgraph construction (NN-Descent / HNSW / Vamana).
    Build,
    /// Merge compute (sampling + Local-Join + merge sort).
    Merge,
    /// Network data exchange (modelled from payload bytes).
    Exchange,
    /// External-storage reads/writes (measured or modelled).
    Storage,
    /// Everything else (scheduling, serialization).
    Other,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Build => "build",
            Phase::Merge => "merge",
            Phase::Exchange => "exchange",
            Phase::Storage => "storage",
            Phase::Other => "other",
        }
    }

    pub fn all() -> [Phase; 5] {
        [
            Phase::Build,
            Phase::Merge,
            Phase::Exchange,
            Phase::Storage,
            Phase::Other,
        ]
    }
}

/// Thread-safe accumulator of per-phase seconds and byte counters.
#[derive(Debug, Default)]
pub struct CostLedger {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    secs: BTreeMap<Phase, f64>,
    bytes_sent: u64,
    bytes_stored: u64,
    /// Paged-storage chunk faults (loads + re-faults after eviction).
    chunk_faults: u64,
    /// Chunks evicted by the residency budget's clock sweep.
    chunk_evictions: u64,
    /// On-disk bytes read by chunk faults (what Phase::Storage bills).
    fault_bytes: u64,
    /// High-water mark of budget-tracked residency (bytes).
    peak_resident: u64,
}

impl CostLedger {
    pub fn new() -> CostLedger {
        CostLedger::default()
    }

    /// Add `secs` to a phase.
    pub fn add(&self, phase: Phase, secs: f64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.secs.entry(phase).or_insert(0.0) += secs;
    }

    /// Time a closure into a phase.
    pub fn time<R>(&self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.add(phase, start.elapsed().as_secs_f64());
        r
    }

    /// Record network payload bytes (the modelled exchange time is added
    /// separately by the link model).
    pub fn add_bytes_sent(&self, bytes: u64) {
        self.inner.lock().unwrap().bytes_sent += bytes;
    }

    /// Record storage payload bytes.
    pub fn add_bytes_stored(&self, bytes: u64) {
        self.inner.lock().unwrap().bytes_stored += bytes;
    }

    /// Record paged-storage activity: chunk faults, evictions, and the
    /// on-disk bytes those faults read (the modelled read time for them
    /// is added separately via [`CostLedger::add`]).
    pub fn add_chunk_faults(&self, faults: u64, evictions: u64, fault_bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.chunk_faults += faults;
        inner.chunk_evictions += evictions;
        inner.fault_bytes += fault_bytes;
    }

    /// Record a residency high-water mark (keeps the maximum seen).
    pub fn note_peak_resident(&self, bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.peak_resident = inner.peak_resident.max(bytes);
    }

    pub fn chunk_faults(&self) -> u64 {
        self.inner.lock().unwrap().chunk_faults
    }

    pub fn chunk_evictions(&self) -> u64 {
        self.inner.lock().unwrap().chunk_evictions
    }

    /// On-disk bytes read by chunk faults.
    pub fn fault_bytes(&self) -> u64 {
        self.inner.lock().unwrap().fault_bytes
    }

    /// High-water mark of budget-tracked residency.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().peak_resident
    }

    pub fn secs(&self, phase: Phase) -> f64 {
        *self.inner.lock().unwrap().secs.get(&phase).unwrap_or(&0.0)
    }

    pub fn total_secs(&self) -> f64 {
        self.inner.lock().unwrap().secs.values().sum()
    }

    pub fn bytes_sent(&self) -> u64 {
        self.inner.lock().unwrap().bytes_sent
    }

    pub fn bytes_stored(&self) -> u64 {
        self.inner.lock().unwrap().bytes_stored
    }

    /// Percentage breakdown (phase -> share of total), Fig. 14's series.
    pub fn breakdown(&self) -> Vec<(Phase, f64)> {
        let total = self.total_secs().max(1e-12);
        Phase::all()
            .into_iter()
            .map(|p| (p, self.secs(p) / total * 100.0))
            .collect()
    }

    /// Merge another ledger into this one (per-node -> cluster totals).
    pub fn absorb(&self, other: &CostLedger) {
        let o = other.inner.lock().unwrap();
        let mut s = self.inner.lock().unwrap();
        for (p, v) in &o.secs {
            *s.secs.entry(*p).or_insert(0.0) += v;
        }
        s.bytes_sent += o.bytes_sent;
        s.bytes_stored += o.bytes_stored;
        s.chunk_faults += o.chunk_faults;
        s.chunk_evictions += o.chunk_evictions;
        s.fault_bytes += o.fault_bytes;
        s.peak_resident = s.peak_resident.max(o.peak_resident);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let l = CostLedger::new();
        l.add(Phase::Build, 1.0);
        l.add(Phase::Build, 0.5);
        l.add(Phase::Exchange, 2.5);
        assert_eq!(l.secs(Phase::Build), 1.5);
        assert_eq!(l.secs(Phase::Exchange), 2.5);
        assert_eq!(l.total_secs(), 4.0);
    }

    #[test]
    fn breakdown_sums_to_hundred() {
        let l = CostLedger::new();
        l.add(Phase::Build, 3.0);
        l.add(Phase::Merge, 1.0);
        let total: f64 = l.breakdown().iter().map(|(_, v)| v).sum();
        assert!((total - 100.0).abs() < 1e-9);
    }

    #[test]
    fn time_measures_closure() {
        let l = CostLedger::new();
        l.time(Phase::Merge, || {
            std::thread::sleep(std::time::Duration::from_millis(5))
        });
        assert!(l.secs(Phase::Merge) >= 0.004);
    }

    #[test]
    fn absorb_combines_ledgers() {
        let a = CostLedger::new();
        let b = CostLedger::new();
        a.add(Phase::Build, 1.0);
        b.add(Phase::Build, 2.0);
        b.add_bytes_sent(100);
        a.absorb(&b);
        assert_eq!(a.secs(Phase::Build), 3.0);
        assert_eq!(a.bytes_sent(), 100);
    }

    #[test]
    fn fault_counters_accumulate_and_absorb() {
        let a = CostLedger::new();
        a.add_chunk_faults(3, 1, 4096);
        a.add_chunk_faults(2, 0, 1024);
        a.note_peak_resident(500);
        a.note_peak_resident(300); // lower: must not regress the peak
        assert_eq!(a.chunk_faults(), 5);
        assert_eq!(a.chunk_evictions(), 1);
        assert_eq!(a.fault_bytes(), 5120);
        assert_eq!(a.peak_resident_bytes(), 500);
        let b = CostLedger::new();
        b.add_chunk_faults(1, 2, 100);
        b.note_peak_resident(900);
        a.absorb(&b);
        assert_eq!(a.chunk_faults(), 6);
        assert_eq!(a.chunk_evictions(), 3);
        assert_eq!(a.fault_bytes(), 5220);
        assert_eq!(a.peak_resident_bytes(), 900);
    }
}

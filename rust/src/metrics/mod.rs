//! Observability: phase-labelled cost accounting plus the always-on
//! metrics substrate (registry, histograms, spans, event journal).
//!
//! The distributed procedure reports *where* time goes (paper Fig. 14:
//! subgraph construction vs merge compute vs data exchange vs storage
//! access). [`CostLedger`] accumulates seconds per [`Phase`], mixing
//! measured wall-clock (compute) and modelled time (network/storage,
//! derived from byte counts and the configured bandwidths). It is
//! backed by the same relaxed atomics as [`registry::Counter`] — per
//! phase nanosecond counters, no lock on the accumulation path — while
//! keeping the original API so callers compile unchanged.
//!
//! The submodules form the `obs` subsystem:
//! - [`registry`]: named counters/gauges/histograms behind one
//!   [`Registry`] with a versioned [`MetricsSnapshot::to_json`] export;
//! - [`histogram`]: lock-free log-bucketed latency histograms
//!   (p50/p95/p99/p999 + exact max, mergeable, snapshot-consistent);
//! - [`span`]: RAII guards timing background work, with nested child
//!   time attributed to the child phase only;
//! - [`events`]: a bounded ring-buffer journal of noteworthy moments
//!   (seals, compactions, checkpoints, budget pressure).

pub mod events;
pub mod histogram;
pub mod registry;
pub mod span;

pub use events::{EventJournal, EventRecord, DEFAULT_JOURNAL_CAP};
pub use histogram::{Histogram, HistogramSnapshot};
pub use registry::{Counter, Gauge, MetricsSnapshot, Registry, SpanSnapshot, SNAPSHOT_VERSION};
pub use span::{Span, SpanGuard, SpanStats};

use std::time::Instant;

/// Cost categories (Fig. 14's breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Subgraph construction (NN-Descent / HNSW / Vamana).
    Build,
    /// Merge compute (sampling + Local-Join + merge sort).
    Merge,
    /// Network data exchange (modelled from payload bytes).
    Exchange,
    /// External-storage reads/writes (measured or modelled).
    Storage,
    /// Everything else (scheduling, serialization).
    Other,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Build => "build",
            Phase::Merge => "merge",
            Phase::Exchange => "exchange",
            Phase::Storage => "storage",
            Phase::Other => "other",
        }
    }

    pub fn all() -> [Phase; 5] {
        [
            Phase::Build,
            Phase::Merge,
            Phase::Exchange,
            Phase::Storage,
            Phase::Other,
        ]
    }

    /// Dense index for fixed-size per-phase arrays.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Phase::Build => 0,
            Phase::Merge => 1,
            Phase::Exchange => 2,
            Phase::Storage => 3,
            Phase::Other => 4,
        }
    }
}

/// Seconds → nanoseconds for the per-phase counters. `round()` keeps
/// short decimal inputs (0.5s → exactly 5e8 ns) exact through the
/// round-trip back to seconds; negatives clamp to 0 via the saturating
/// float→int cast.
#[inline]
fn secs_to_ns(secs: f64) -> u64 {
    (secs * 1e9).round() as u64
}

/// Thread-safe accumulator of per-phase seconds and byte counters.
///
/// Every field is a relaxed atomic: `add`/`add_bytes_*` on the hot
/// path are single `fetch_add`s (the former `Mutex<BTreeMap>` is
/// gone). Seconds are stored as nanosecond counters; at the magnitudes
/// a build ledger sees (minutes to hours), the f64 round-trip is exact
/// to well below a microsecond.
#[derive(Debug, Default)]
pub struct CostLedger {
    phase_ns: [Counter; 5],
    bytes_sent: Counter,
    bytes_stored: Counter,
    /// Paged-storage chunk faults (loads + re-faults after eviction).
    chunk_faults: Counter,
    /// Chunks evicted by the residency budget's clock sweep.
    chunk_evictions: Counter,
    /// On-disk bytes read by chunk faults (what Phase::Storage bills).
    fault_bytes: Counter,
    /// High-water mark of budget-tracked residency (bytes).
    peak_resident: Counter,
}

impl CostLedger {
    pub fn new() -> CostLedger {
        CostLedger::default()
    }

    /// Add `secs` to a phase.
    pub fn add(&self, phase: Phase, secs: f64) {
        self.phase_ns[phase.idx()].add(secs_to_ns(secs));
    }

    /// Time a closure into a phase.
    pub fn time<R>(&self, phase: Phase, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.add(phase, start.elapsed().as_secs_f64());
        r
    }

    /// Record network payload bytes (the modelled exchange time is added
    /// separately by the link model).
    pub fn add_bytes_sent(&self, bytes: u64) {
        self.bytes_sent.add(bytes);
    }

    /// Record storage payload bytes.
    pub fn add_bytes_stored(&self, bytes: u64) {
        self.bytes_stored.add(bytes);
    }

    /// Record paged-storage activity: chunk faults, evictions, and the
    /// on-disk bytes those faults read (the modelled read time for them
    /// is added separately via [`CostLedger::add`]).
    pub fn add_chunk_faults(&self, faults: u64, evictions: u64, fault_bytes: u64) {
        self.chunk_faults.add(faults);
        self.chunk_evictions.add(evictions);
        self.fault_bytes.add(fault_bytes);
    }

    /// Record a residency high-water mark (keeps the maximum seen).
    pub fn note_peak_resident(&self, bytes: u64) {
        self.peak_resident.fetch_max(bytes);
    }

    pub fn chunk_faults(&self) -> u64 {
        self.chunk_faults.get()
    }

    pub fn chunk_evictions(&self) -> u64 {
        self.chunk_evictions.get()
    }

    /// On-disk bytes read by chunk faults.
    pub fn fault_bytes(&self) -> u64 {
        self.fault_bytes.get()
    }

    /// High-water mark of budget-tracked residency.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident.get()
    }

    pub fn secs(&self, phase: Phase) -> f64 {
        self.phase_ns[phase.idx()].get() as f64 / 1e9
    }

    pub fn total_secs(&self) -> f64 {
        Phase::all().into_iter().map(|p| self.secs(p)).sum()
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.get()
    }

    pub fn bytes_stored(&self) -> u64 {
        self.bytes_stored.get()
    }

    /// Percentage breakdown (phase -> share of total), Fig. 14's series.
    pub fn breakdown(&self) -> Vec<(Phase, f64)> {
        let total = self.total_secs().max(1e-12);
        Phase::all()
            .into_iter()
            .map(|p| (p, self.secs(p) / total * 100.0))
            .collect()
    }

    /// Merge another ledger into this one (per-node -> cluster totals).
    pub fn absorb(&self, other: &CostLedger) {
        for p in Phase::all() {
            self.phase_ns[p.idx()].add(other.phase_ns[p.idx()].get());
        }
        self.bytes_sent.add(other.bytes_sent.get());
        self.bytes_stored.add(other.bytes_stored.get());
        self.chunk_faults.add(other.chunk_faults.get());
        self.chunk_evictions.add(other.chunk_evictions.get());
        self.fault_bytes.add(other.fault_bytes.get());
        self.peak_resident.fetch_max(other.peak_resident.get());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let l = CostLedger::new();
        l.add(Phase::Build, 1.0);
        l.add(Phase::Build, 0.5);
        l.add(Phase::Exchange, 2.5);
        assert_eq!(l.secs(Phase::Build), 1.5);
        assert_eq!(l.secs(Phase::Exchange), 2.5);
        assert_eq!(l.total_secs(), 4.0);
    }

    #[test]
    fn breakdown_sums_to_hundred() {
        let l = CostLedger::new();
        l.add(Phase::Build, 3.0);
        l.add(Phase::Merge, 1.0);
        let total: f64 = l.breakdown().iter().map(|(_, v)| v).sum();
        assert!((total - 100.0).abs() < 1e-9);
    }

    #[test]
    fn time_measures_closure() {
        let l = CostLedger::new();
        l.time(Phase::Merge, || {
            std::thread::sleep(std::time::Duration::from_millis(5))
        });
        assert!(l.secs(Phase::Merge) >= 0.004);
    }

    #[test]
    fn absorb_combines_ledgers() {
        let a = CostLedger::new();
        let b = CostLedger::new();
        a.add(Phase::Build, 1.0);
        b.add(Phase::Build, 2.0);
        b.add_bytes_sent(100);
        a.absorb(&b);
        assert_eq!(a.secs(Phase::Build), 3.0);
        assert_eq!(a.bytes_sent(), 100);
    }

    #[test]
    fn fault_counters_accumulate_and_absorb() {
        let a = CostLedger::new();
        a.add_chunk_faults(3, 1, 4096);
        a.add_chunk_faults(2, 0, 1024);
        a.note_peak_resident(500);
        a.note_peak_resident(300); // lower: must not regress the peak
        assert_eq!(a.chunk_faults(), 5);
        assert_eq!(a.chunk_evictions(), 1);
        assert_eq!(a.fault_bytes(), 5120);
        assert_eq!(a.peak_resident_bytes(), 500);
        let b = CostLedger::new();
        b.add_chunk_faults(1, 2, 100);
        b.note_peak_resident(900);
        a.absorb(&b);
        assert_eq!(a.chunk_faults(), 6);
        assert_eq!(a.chunk_evictions(), 3);
        assert_eq!(a.fault_bytes(), 5220);
        assert_eq!(a.peak_resident_bytes(), 900);
    }

    #[test]
    fn negative_seconds_clamp_to_zero() {
        let l = CostLedger::new();
        l.add(Phase::Other, -1.0);
        assert_eq!(l.secs(Phase::Other), 0.0);
    }
}

//! Run configuration: a TOML-lite format (flat `key = value` pairs under
//! `[section]` headers — the subset actually needed for experiment
//! configs) plus typed accessors and the [`RunConfig`] used by the CLI
//! and examples. JSON configs are accepted too (via `util::json`).

use crate::construction::NnDescentParams;
use crate::dataset::DatasetFamily;
use crate::distance::Metric;
use crate::merge::MergeParams;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed flat config: `section.key -> raw string value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigMap {
    values: BTreeMap<String, String>,
}

impl ConfigMap {
    /// Parse TOML-lite text: `[section]` headers, `key = value` lines,
    /// `#` comments, quoted or bare scalar values.
    pub fn parse(text: &str) -> Result<ConfigMap> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value'", lineno + 1);
            };
            let key = key.trim();
            let mut value = value.trim().to_string();
            if (value.starts_with('"') && value.ends_with('"') && value.len() >= 2)
                || (value.starts_with('\'') && value.ends_with('\'') && value.len() >= 2)
            {
                value = value[1..value.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, value);
        }
        Ok(ConfigMap { values })
    }

    pub fn load(path: &Path) -> Result<ConfigMap> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    /// Override a value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

/// Which search structure streaming segments maintain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamGraphMode {
    /// Raw k-NN graphs; search runs over the unplugged adjacency.
    Knn,
    /// Diversified indexing graphs (Eq. 1 pruning after each merge).
    Index,
}

impl StreamGraphMode {
    pub fn name(&self) -> &'static str {
        match self {
            StreamGraphMode::Knn => "knn",
            StreamGraphMode::Index => "index",
        }
    }

    pub fn from_name(s: &str) -> Option<StreamGraphMode> {
        match s.to_ascii_lowercase().as_str() {
            "knn" => Some(StreamGraphMode::Knn),
            "index" | "indexing" => Some(StreamGraphMode::Index),
            _ => None,
        }
    }
}

/// Configuration of the online streaming subsystem (`stream::`): the
/// LSM-of-subgraphs segment log. `segment_size` trades ingest latency
/// (seal/compaction pauses grow with it) against search fan-out (more,
/// smaller segments must each be probed); `merge.lambda` plays the same
/// cost/quality role it plays in the batch pipeline, once per
/// compaction.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Memtable capacity: vectors buffered before sealing a segment.
    pub segment_size: usize,
    /// Seal builds brute-force up to this size, NN-Descent above it.
    pub brute_threshold: usize,
    /// Search structure kept per segment.
    pub mode: StreamGraphMode,
    /// Diversification alpha (Index mode; Vamana-style, typically 1.2).
    pub alpha: f32,
    /// Degree bound of the per-segment index graph.
    pub max_degree: usize,
    /// Default beam width for `StreamingIndex::search`.
    pub ef: usize,
    /// Seal worker threads: memtable freezes are handed to this many
    /// background builders so `insert` never pays for graph
    /// construction. `0` builds inline on the inserting thread
    /// (deterministic; the pre-off-thread-seal behaviour).
    pub seal_threads: usize,
    /// Dead-fraction compaction trigger: when a segment's tombstoned
    /// share reaches this fraction, `tick()` rewrites that segment in
    /// place (purge + repair, level preserved) *before* consulting the
    /// geometric schedule — deletes and upserts reclaim space without
    /// waiting for a same-level partner. `0.0` disables the trigger.
    pub compact_dead_fraction: f64,
    /// Keep an SQ8 resident tier per segment (L2 only): beam search
    /// runs over the codes, and only the final `topk + rerank_slack`
    /// candidates fault full-precision rows for exact rerank. A
    /// runtime knob — derived from segment data at seal/restore, never
    /// part of persisted graph structure.
    pub quantized_tier: bool,
    /// Extra candidates the SQ8 beam fetches beyond `topk` for exact
    /// rerank (the SQ8 error/rerank-slack contract: quantization can
    /// misrank within the reconstruction error, so the true top-k is
    /// recovered from a slightly widened pool).
    pub rerank_slack: usize,
    /// Group-commit window of the write-ahead log, in microseconds:
    /// how long the first committer of a group waits for more appends
    /// before paying the single fsync that makes the whole group
    /// durable. Larger windows amortize fsyncs under concurrent ingest
    /// at the cost of per-op ack latency; `0` flushes immediately
    /// (still batching whatever accumulated). Only consulted when a
    /// WAL is attached (`StreamingIndex::attach_durability`).
    pub wal_group_commit_us: u64,
    /// Compaction / graph parameters (k, lambda, delta, iters, seed).
    pub merge: MergeParams,
    /// Segment-build parameters (NN-Descent above `brute_threshold`).
    pub nnd: NnDescentParams,
}

impl Default for StreamConfig {
    fn default() -> Self {
        let merge = MergeParams::default();
        StreamConfig {
            segment_size: 1024,
            brute_threshold: 512,
            mode: StreamGraphMode::Knn,
            alpha: 1.2,
            max_degree: merge.k,
            ef: 64,
            seal_threads: 1,
            compact_dead_fraction: 0.25,
            quantized_tier: false,
            rerank_slack: 32,
            wal_group_commit_us: 200,
            merge,
            nnd: NnDescentParams::default(),
        }
    }
}

impl StreamConfig {
    /// Build from a parsed [`ConfigMap`] `[stream]` section; missing keys
    /// keep defaults. The `[merge]` keys feed the compaction parameters
    /// through [`RunConfig::from_map`].
    pub fn apply_map(&mut self, map: &ConfigMap) -> Result<()> {
        if let Some(v) = map.get_usize("stream.segment_size")? {
            if v == 0 {
                bail!("stream.segment_size must be positive");
            }
            self.segment_size = v;
        }
        if let Some(v) = map.get_usize("stream.brute_threshold")? {
            self.brute_threshold = v;
        }
        if let Some(name) = map.get("stream.mode") {
            self.mode = StreamGraphMode::from_name(name)
                .with_context(|| format!("unknown stream mode '{name}'"))?;
        }
        if let Some(v) = map.get_f64("stream.alpha")? {
            self.alpha = v as f32;
        }
        if let Some(v) = map.get_usize("stream.max_degree")? {
            self.max_degree = v;
        }
        if let Some(v) = map.get_usize("stream.ef")? {
            self.ef = v;
        }
        if let Some(v) = map.get_usize("stream.seal_threads")? {
            self.seal_threads = v;
        }
        if let Some(v) = map.get_f64("stream.compact_dead_fraction")? {
            if !(0.0..=1.0).contains(&v) {
                bail!("stream.compact_dead_fraction must be in [0, 1], got {v}");
            }
            self.compact_dead_fraction = v;
        }
        if let Some(v) = map.get("stream.quantized_tier") {
            self.quantized_tier = match v.to_ascii_lowercase().as_str() {
                "true" | "1" | "on" | "yes" => true,
                "false" | "0" | "off" | "no" => false,
                _ => bail!("stream.quantized_tier must be a boolean, got '{v}'"),
            };
        }
        if let Some(v) = map.get_usize("stream.rerank_slack")? {
            self.rerank_slack = v;
        }
        if let Some(v) = map.get_u64("stream.wal_group_commit_us")? {
            self.wal_group_commit_us = v;
        }
        Ok(())
    }

    /// Fingerprint of the parameters that shape persisted graph state
    /// (`stream::persist` stores it in the checkpoint manifest; restore
    /// refuses a mismatch, since segments built under different k /
    /// lambda / seeds would silently mix incompatible graphs). Runtime
    /// knobs that do not affect stored structure — `ef`,
    /// `seal_threads`, `compact_dead_fraction`, `quantized_tier`,
    /// `rerank_slack` (the SQ8 tier is *derived* from segment data, so
    /// a restored log may toggle it freely), `wal_group_commit_us`
    /// (fsync batching changes latency, never bytes) — are
    /// deliberately excluded, so a restored log may retune them freely.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a 64 over the field values in a fixed order.
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        mix(1); // fingerprint schema version
        mix(self.segment_size as u64);
        mix(self.brute_threshold as u64);
        mix(match self.mode {
            StreamGraphMode::Knn => 0,
            StreamGraphMode::Index => 1,
        });
        mix(self.alpha.to_bits() as u64);
        mix(self.max_degree as u64);
        for p in [
            (
                self.merge.k,
                self.merge.lambda,
                self.merge.delta,
                self.merge.max_iters,
                self.merge.seed,
            ),
            (
                self.nnd.k,
                self.nnd.lambda,
                self.nnd.delta,
                self.nnd.max_iters,
                self.nnd.seed,
            ),
        ] {
            mix(p.0 as u64);
            mix(p.1 as u64);
            mix(p.2.to_bits());
            mix(p.3 as u64);
            mix(p.4);
        }
        h
    }
}

/// Admission-control and serving knobs of the [`service`] layer (the
/// `serve` CLI mode and any embedded [`Service`]).
///
/// All limits act per [`Service`] instance. Searches are never
/// rejected: past 50% pressure the beam width degrades linearly toward
/// `topk`, and an over-committed search class (more than
/// `max_inflight_search` concurrent searches) runs fully degraded.
/// Ingest (insert/delete/upsert) is rejected with `Overloaded` +
/// `retry_after_ms` once `max_inflight_ingest` operations are in
/// flight or pressure reaches 1.0.
///
/// [`Service`]: crate::service::Service
/// [`service`]: crate::service
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeConfig {
    /// Concurrent searches before the class is over-committed and new
    /// searches run at the fully degraded beam width (`ef == topk`).
    pub max_inflight_search: usize,
    /// Concurrent ingest operations admitted; the rest see
    /// `Overloaded`.
    pub max_inflight_ingest: usize,
    /// Seal backlog (frozen batches queued for off-thread build) that
    /// counts as pressure 1.0. The engine's own dispatch valve blocks
    /// inserts at `2 * seal_threads + 2`, so the default sits above
    /// any common valve: batch drivers never trip it accidentally,
    /// while a server can lower it to shed load before the valve
    /// stalls a connection thread.
    pub max_seal_backlog: usize,
    /// Retry hint attached to `Overloaded` responses, milliseconds.
    pub retry_after_ms: u64,
    /// `serve` mode: checkpoint the log every this many seconds when a
    /// checkpoint dir is configured (0 = only at shutdown).
    pub checkpoint_interval_s: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_inflight_search: 64,
            max_inflight_ingest: 16,
            max_seal_backlog: 16,
            retry_after_ms: 25,
            checkpoint_interval_s: 0.0,
        }
    }
}

impl ServeConfig {
    /// Admission control effectively off: never reject, never degrade.
    /// The batch ingest driver uses this so a `Service`-routed run
    /// behaves exactly like the direct-engine path it replaced.
    pub fn unbounded() -> ServeConfig {
        ServeConfig {
            max_inflight_search: usize::MAX,
            max_inflight_ingest: usize::MAX,
            max_seal_backlog: usize::MAX,
            retry_after_ms: 1,
            checkpoint_interval_s: 0.0,
        }
    }

    /// Build from a parsed [`ConfigMap`] `[serve]` section; missing
    /// keys keep defaults.
    pub fn apply_map(&mut self, map: &ConfigMap) -> Result<()> {
        if let Some(v) = map.get_usize("serve.max_inflight_search")? {
            self.max_inflight_search = v;
        }
        if let Some(v) = map.get_usize("serve.max_inflight_ingest")? {
            self.max_inflight_ingest = v;
        }
        if let Some(v) = map.get_usize("serve.max_seal_backlog")? {
            if v == 0 {
                bail!("serve.max_seal_backlog must be positive");
            }
            self.max_seal_backlog = v;
        }
        if let Some(v) = map.get_u64("serve.retry_after_ms")? {
            self.retry_after_ms = v;
        }
        if let Some(v) = map.get_f64("serve.checkpoint_interval_s")? {
            if v < 0.0 {
                bail!("serve.checkpoint_interval_s must be >= 0, got {v}");
            }
            self.checkpoint_interval_s = v;
        }
        Ok(())
    }
}

/// A complete run configuration for the coordinator.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Synthetic dataset family.
    pub family: DatasetFamily,
    /// Number of base vectors.
    pub n: usize,
    /// Distance metric.
    pub metric: Metric,
    /// Number of subsets / simulated nodes.
    pub parts: usize,
    /// Merge parameters (k, lambda, delta, iters, seed).
    pub merge: MergeParams,
    /// Subgraph-construction parameters.
    pub nnd: NnDescentParams,
    /// Network bandwidth between nodes, bits per second (paper: 1 Gbps).
    pub bandwidth_bps: f64,
    /// Per-message network latency, seconds.
    pub latency_s: f64,
    /// External-storage throughput, bytes/s (paper's SSD: ~7 GB/s read).
    pub storage_bps: f64,
    /// Scratch directory for out-of-core spills.
    pub scratch_dir: String,
    /// Residency budget (bytes) for everything the out-of-core mode
    /// pages back in — vector chunks and graph blocks alike. 0 means
    /// unbounded. The paper's Sec. IV bound is ~2/p of the dataset.
    pub memory_budget: u64,
    /// Dataset seed.
    pub seed: u64,
    /// Online streaming subsystem parameters.
    pub stream: StreamConfig,
    /// Service-layer admission control (`serve` mode knobs).
    pub serve: ServeConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            family: DatasetFamily::Sift,
            n: 10_000,
            metric: Metric::L2,
            parts: 3,
            merge: MergeParams::default(),
            nnd: NnDescentParams::default(),
            bandwidth_bps: 1e9,   // 1000 Mbps, Sec. V-E
            latency_s: 100e-6,    // typical same-rack RTT/2
            storage_bps: 7.45e9,  // paper's SSD sequential read
            scratch_dir: std::env::temp_dir()
                .join("knn-merge-scratch")
                .to_string_lossy()
                .to_string(),
            memory_budget: 0,
            seed: 42,
            stream: StreamConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

impl RunConfig {
    /// Build from a parsed [`ConfigMap`]; missing keys keep defaults.
    pub fn from_map(map: &ConfigMap) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(name) = map.get("dataset.family") {
            cfg.family = DatasetFamily::from_name(name)
                .with_context(|| format!("unknown dataset family '{name}'"))?;
        }
        if let Some(v) = map.get_usize("dataset.n")? {
            cfg.n = v;
        }
        if let Some(v) = map.get_u64("dataset.seed")? {
            cfg.seed = v;
        }
        if let Some(name) = map.get("dataset.metric") {
            cfg.metric =
                Metric::from_name(name).with_context(|| format!("unknown metric '{name}'"))?;
        }
        if let Some(v) = map.get_usize("run.parts")? {
            cfg.parts = v;
        }
        if let Some(v) = map.get_usize("merge.k")? {
            cfg.merge.k = v;
            cfg.nnd.k = v;
        }
        if let Some(v) = map.get_usize("merge.lambda")? {
            cfg.merge.lambda = v;
            cfg.nnd.lambda = v;
        }
        if let Some(v) = map.get_f64("merge.delta")? {
            cfg.merge.delta = v;
            cfg.nnd.delta = v;
        }
        if let Some(v) = map.get_usize("merge.max_iters")? {
            cfg.merge.max_iters = v;
            cfg.nnd.max_iters = v;
        }
        if let Some(v) = map.get_u64("merge.seed")? {
            cfg.merge.seed = v;
            cfg.nnd.seed = v;
        }
        if let Some(v) = map.get_f64("network.bandwidth_gbps")? {
            cfg.bandwidth_bps = v * 1e9;
        }
        if let Some(v) = map.get_f64("network.latency_us")? {
            cfg.latency_s = v * 1e-6;
        }
        if let Some(v) = map.get_f64("storage.bandwidth_gbps")? {
            cfg.storage_bps = v * 1e9;
        }
        if let Some(v) = map.get("storage.scratch_dir") {
            cfg.scratch_dir = v.to_string();
        }
        if let Some(v) = map.get_u64("storage.memory_budget_mib")? {
            cfg.memory_budget = v << 20;
        }
        // The [merge] keys drive compaction too; [stream] keys override
        // the subsystem's own knobs.
        cfg.stream.merge = cfg.merge;
        cfg.stream.nnd = cfg.nnd;
        cfg.stream.max_degree = cfg.merge.k;
        cfg.stream.apply_map(map)?;
        cfg.serve.apply_map(map)?;
        Ok(cfg)
    }

    /// Load from a TOML-lite file.
    pub fn load(path: &Path) -> Result<RunConfig> {
        Self::from_map(&ConfigMap::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[dataset]
family = "gist"
n = 5000
metric = 'l2'

[run]
parts = 5

[merge]
k = 40
lambda = 16

[network]
bandwidth_gbps = 10
latency_us = 50
"#;

    #[test]
    fn parses_sections_and_values() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(map.get("dataset.family"), Some("gist"));
        assert_eq!(map.get_usize("dataset.n").unwrap(), Some(5000));
        assert_eq!(map.get("dataset.metric"), Some("l2"));
        assert_eq!(map.get_usize("run.parts").unwrap(), Some(5));
    }

    #[test]
    fn run_config_from_map() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.family, DatasetFamily::Gist);
        assert_eq!(cfg.n, 5000);
        assert_eq!(cfg.parts, 5);
        assert_eq!(cfg.merge.k, 40);
        assert_eq!(cfg.merge.lambda, 16);
        assert_eq!(cfg.nnd.k, 40);
        assert!((cfg.bandwidth_bps - 10e9).abs() < 1.0);
        assert!((cfg.latency_s - 50e-6).abs() < 1e-12);
    }

    #[test]
    fn serve_config_from_map() {
        let map = ConfigMap::parse(
            "[serve]\nmax_inflight_search = 8\nmax_inflight_ingest = 2\n\
             max_seal_backlog = 4\nretry_after_ms = 7\ncheckpoint_interval_s = 1.5\n",
        )
        .unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.serve.max_inflight_search, 8);
        assert_eq!(cfg.serve.max_inflight_ingest, 2);
        assert_eq!(cfg.serve.max_seal_backlog, 4);
        assert_eq!(cfg.serve.retry_after_ms, 7);
        assert!((cfg.serve.checkpoint_interval_s - 1.5).abs() < 1e-12);

        let bad = ConfigMap::parse("[serve]\nmax_seal_backlog = 0\n").unwrap();
        assert!(RunConfig::from_map(&bad).is_err());
        let neg = ConfigMap::parse("[serve]\ncheckpoint_interval_s = -1\n").unwrap();
        assert!(RunConfig::from_map(&neg).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigMap::parse("[unclosed").is_err());
        assert!(ConfigMap::parse("novalue").is_err());
    }

    #[test]
    fn rejects_unknown_family() {
        let map = ConfigMap::parse("[dataset]\nfamily = bogus").unwrap();
        assert!(RunConfig::from_map(&map).is_err());
    }

    #[test]
    fn cli_set_overrides() {
        let mut map = ConfigMap::parse(SAMPLE).unwrap();
        map.set("merge.k", "64");
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.merge.k, 64);
    }

    #[test]
    fn stream_config_from_map() {
        let text = r#"
[merge]
k = 24
lambda = 12

[stream]
segment_size = 2048
mode = "index"
alpha = 1.3
ef = 96
seal_threads = 3
"#;
        let map = ConfigMap::parse(text).unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.stream.segment_size, 2048);
        assert_eq!(cfg.stream.mode, StreamGraphMode::Index);
        assert!((cfg.stream.alpha - 1.3).abs() < 1e-6);
        assert_eq!(cfg.stream.ef, 96);
        assert_eq!(cfg.stream.seal_threads, 3);
        // merge keys propagate into the compaction parameters
        assert_eq!(cfg.stream.merge.k, 24);
        assert_eq!(cfg.stream.merge.lambda, 12);
        assert_eq!(cfg.stream.max_degree, 24);
    }

    #[test]
    fn stream_config_rejects_bad_values() {
        let map = ConfigMap::parse("[stream]\nsegment_size = 0").unwrap();
        assert!(RunConfig::from_map(&map).is_err());
        let map = ConfigMap::parse("[stream]\nmode = bogus").unwrap();
        assert!(RunConfig::from_map(&map).is_err());
        let map = ConfigMap::parse("[stream]\ncompact_dead_fraction = 1.5").unwrap();
        assert!(RunConfig::from_map(&map).is_err());
    }

    #[test]
    fn compact_dead_fraction_parses_and_disables() {
        let cfg = RunConfig::default();
        assert!((cfg.stream.compact_dead_fraction - 0.25).abs() < 1e-9);
        let map = ConfigMap::parse("[stream]\ncompact_dead_fraction = 0").unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.stream.compact_dead_fraction, 0.0, "0 disables");
    }

    #[test]
    fn fingerprint_tracks_graph_shaping_knobs_only() {
        let base = StreamConfig::default();
        assert_eq!(base.fingerprint(), StreamConfig::default().fingerprint());
        // Structure-shaping changes move the fingerprint...
        let mut k = base.clone();
        k.merge.k += 1;
        assert_ne!(k.fingerprint(), base.fingerprint());
        let mut seg = base.clone();
        seg.segment_size += 1;
        assert_ne!(seg.fingerprint(), base.fingerprint());
        let mut mode = base.clone();
        mode.mode = StreamGraphMode::Index;
        assert_ne!(mode.fingerprint(), base.fingerprint());
        // ...runtime-only knobs do not.
        let mut tunable = base.clone();
        tunable.ef = 999;
        tunable.seal_threads = 7;
        tunable.compact_dead_fraction = 0.9;
        tunable.quantized_tier = true;
        tunable.rerank_slack = 128;
        tunable.wal_group_commit_us = 5_000;
        assert_eq!(tunable.fingerprint(), base.fingerprint());
    }

    #[test]
    fn defaults_match_paper_testbed() {
        let cfg = RunConfig::default();
        assert!((cfg.bandwidth_bps - 1e9).abs() < 1.0, "1000 Mbps default");
        assert_eq!(cfg.parts, 3);
        assert_eq!(cfg.memory_budget, 0, "unbounded residency by default");
    }

    #[test]
    fn memory_budget_parses_in_mib() {
        let map = ConfigMap::parse("[storage]\nmemory_budget_mib = 64").unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.memory_budget, 64 << 20);
    }
}

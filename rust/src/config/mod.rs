//! Run configuration: a TOML-lite format (flat `key = value` pairs under
//! `[section]` headers — the subset actually needed for experiment
//! configs) plus typed accessors and the [`RunConfig`] used by the CLI
//! and examples. JSON configs are accepted too (via `util::json`).

use crate::construction::NnDescentParams;
use crate::dataset::DatasetFamily;
use crate::distance::Metric;
use crate::merge::MergeParams;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed flat config: `section.key -> raw string value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigMap {
    values: BTreeMap<String, String>,
}

impl ConfigMap {
    /// Parse TOML-lite text: `[section]` headers, `key = value` lines,
    /// `#` comments, quoted or bare scalar values.
    pub fn parse(text: &str) -> Result<ConfigMap> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value'", lineno + 1);
            };
            let key = key.trim();
            let mut value = value.trim().to_string();
            if (value.starts_with('"') && value.ends_with('"') && value.len() >= 2)
                || (value.starts_with('\'') && value.ends_with('\'') && value.len() >= 2)
            {
                value = value[1..value.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, value);
        }
        Ok(ConfigMap { values })
    }

    pub fn load(path: &Path) -> Result<ConfigMap> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("{key} = {v}")))
            .transpose()
    }

    /// Override a value (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

/// A complete run configuration for the coordinator.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Synthetic dataset family.
    pub family: DatasetFamily,
    /// Number of base vectors.
    pub n: usize,
    /// Distance metric.
    pub metric: Metric,
    /// Number of subsets / simulated nodes.
    pub parts: usize,
    /// Merge parameters (k, lambda, delta, iters, seed).
    pub merge: MergeParams,
    /// Subgraph-construction parameters.
    pub nnd: NnDescentParams,
    /// Network bandwidth between nodes, bits per second (paper: 1 Gbps).
    pub bandwidth_bps: f64,
    /// Per-message network latency, seconds.
    pub latency_s: f64,
    /// External-storage throughput, bytes/s (paper's SSD: ~7 GB/s read).
    pub storage_bps: f64,
    /// Scratch directory for out-of-core spills.
    pub scratch_dir: String,
    /// Dataset seed.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            family: DatasetFamily::Sift,
            n: 10_000,
            metric: Metric::L2,
            parts: 3,
            merge: MergeParams::default(),
            nnd: NnDescentParams::default(),
            bandwidth_bps: 1e9,   // 1000 Mbps, Sec. V-E
            latency_s: 100e-6,    // typical same-rack RTT/2
            storage_bps: 7.45e9,  // paper's SSD sequential read
            scratch_dir: std::env::temp_dir()
                .join("knn-merge-scratch")
                .to_string_lossy()
                .to_string(),
            seed: 42,
        }
    }
}

impl RunConfig {
    /// Build from a parsed [`ConfigMap`]; missing keys keep defaults.
    pub fn from_map(map: &ConfigMap) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(name) = map.get("dataset.family") {
            cfg.family = DatasetFamily::from_name(name)
                .with_context(|| format!("unknown dataset family '{name}'"))?;
        }
        if let Some(v) = map.get_usize("dataset.n")? {
            cfg.n = v;
        }
        if let Some(v) = map.get_u64("dataset.seed")? {
            cfg.seed = v;
        }
        if let Some(name) = map.get("dataset.metric") {
            cfg.metric =
                Metric::from_name(name).with_context(|| format!("unknown metric '{name}'"))?;
        }
        if let Some(v) = map.get_usize("run.parts")? {
            cfg.parts = v;
        }
        if let Some(v) = map.get_usize("merge.k")? {
            cfg.merge.k = v;
            cfg.nnd.k = v;
        }
        if let Some(v) = map.get_usize("merge.lambda")? {
            cfg.merge.lambda = v;
            cfg.nnd.lambda = v;
        }
        if let Some(v) = map.get_f64("merge.delta")? {
            cfg.merge.delta = v;
            cfg.nnd.delta = v;
        }
        if let Some(v) = map.get_usize("merge.max_iters")? {
            cfg.merge.max_iters = v;
            cfg.nnd.max_iters = v;
        }
        if let Some(v) = map.get_u64("merge.seed")? {
            cfg.merge.seed = v;
            cfg.nnd.seed = v;
        }
        if let Some(v) = map.get_f64("network.bandwidth_gbps")? {
            cfg.bandwidth_bps = v * 1e9;
        }
        if let Some(v) = map.get_f64("network.latency_us")? {
            cfg.latency_s = v * 1e-6;
        }
        if let Some(v) = map.get_f64("storage.bandwidth_gbps")? {
            cfg.storage_bps = v * 1e9;
        }
        if let Some(v) = map.get("storage.scratch_dir") {
            cfg.scratch_dir = v.to_string();
        }
        Ok(cfg)
    }

    /// Load from a TOML-lite file.
    pub fn load(path: &Path) -> Result<RunConfig> {
        Self::from_map(&ConfigMap::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[dataset]
family = "gist"
n = 5000
metric = 'l2'

[run]
parts = 5

[merge]
k = 40
lambda = 16

[network]
bandwidth_gbps = 10
latency_us = 50
"#;

    #[test]
    fn parses_sections_and_values() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(map.get("dataset.family"), Some("gist"));
        assert_eq!(map.get_usize("dataset.n").unwrap(), Some(5000));
        assert_eq!(map.get("dataset.metric"), Some("l2"));
        assert_eq!(map.get_usize("run.parts").unwrap(), Some(5));
    }

    #[test]
    fn run_config_from_map() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.family, DatasetFamily::Gist);
        assert_eq!(cfg.n, 5000);
        assert_eq!(cfg.parts, 5);
        assert_eq!(cfg.merge.k, 40);
        assert_eq!(cfg.merge.lambda, 16);
        assert_eq!(cfg.nnd.k, 40);
        assert!((cfg.bandwidth_bps - 10e9).abs() < 1.0);
        assert!((cfg.latency_s - 50e-6).abs() < 1e-12);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigMap::parse("[unclosed").is_err());
        assert!(ConfigMap::parse("novalue").is_err());
    }

    #[test]
    fn rejects_unknown_family() {
        let map = ConfigMap::parse("[dataset]\nfamily = bogus").unwrap();
        assert!(RunConfig::from_map(&map).is_err());
    }

    #[test]
    fn cli_set_overrides() {
        let mut map = ConfigMap::parse(SAMPLE).unwrap();
        map.set("merge.k", "64");
        let cfg = RunConfig::from_map(&map).unwrap();
        assert_eq!(cfg.merge.k, 64);
    }

    #[test]
    fn defaults_match_paper_testbed() {
        let cfg = RunConfig::default();
        assert!((cfg.bandwidth_bps - 1e9).abs() < 1.0, "1000 Mbps default");
        assert_eq!(cfg.parts, 3);
    }
}

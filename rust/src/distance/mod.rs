//! Distance metrics and the batched distance engine abstraction.
//!
//! Every construction / merge algorithm in the crate is generic over
//! [`Metric`]; the Local-Join hot path additionally uses a
//! [`DistanceEngine`] so batched candidate blocks can be routed either to
//! tight scalar loops ([`ScalarEngine`]) or to the AOT-compiled
//! XLA/Pallas kernel (`runtime::XlaEngine`). Block-shaped evaluations
//! (one query vs. many rows, full cross blocks, SQ8 codes) go through
//! the runtime-dispatched SIMD kernels in [`kernels`].

pub mod engine;
pub mod kernels;

pub use engine::{DistanceEngine, NormExpandEngine, ScalarEngine};
pub use kernels::{kernel_name, one_to_many_l2, one_to_many_l2_sq8, KernelKind};

/// Distance metric over f32 vectors. Smaller = closer everywhere in the
/// crate (the paper's convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Squared Euclidean distance (monotone with L2; what the paper's
    /// datasets use).
    L2,
    /// Negative inner product (so smaller = more similar).
    InnerProduct,
    /// Cosine distance `1 - cos(a, b)`.
    Cosine,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::InnerProduct => "ip",
            Metric::Cosine => "cosine",
        }
    }

    pub fn from_name(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "l2" => Some(Metric::L2),
            "ip" | "innerproduct" | "inner_product" => Some(Metric::InnerProduct),
            "cos" | "cosine" => Some(Metric::Cosine),
            _ => None,
        }
    }

    /// Compute the distance between two vectors.
    #[inline]
    pub fn distance(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::L2 => l2_sq(a, b),
            Metric::InnerProduct => -dot(a, b),
            Metric::Cosine => cosine_dist(a, b),
        }
    }
}

/// Squared L2 distance, 8-wide accumulator blocks over `chunks_exact`
/// — the shape LLVM turns into packed `vsubps`/`vfmadd` at the
/// x86-64-v3 baseline this workspace compiles with (see
/// `.cargo/config.toml`; EXPERIMENTS.md §Perf has the measurements).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            let d = xa[j] - xb[j];
            acc[j] = d.mul_add(d, acc[j]);
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        tail += d * d;
    }
    let s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    s + tail
}

/// Dot product, 8-wide FMA accumulators (same codegen shape as
/// [`l2_sq`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            acc[j] = xa[j].mul_add(xb[j], acc[j]);
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    let s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    s + tail
}

/// Cosine distance `1 - <a,b>/(|a||b|)`; zero vectors yield distance 1.
#[inline]
pub fn cosine_dist(a: &[f32], b: &[f32]) -> f32 {
    let ab = dot(a, b);
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - ab / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_property;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.gen_normal()).collect()
    }

    #[test]
    fn l2_matches_naive() {
        check_property("l2-naive", 100, |rng| {
            let d = 1 + rng.gen_range(300);
            let a = rand_vec(rng, d);
            let b = rand_vec(rng, d);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let fast = l2_sq(&a, &b);
            assert!(
                (naive - fast).abs() <= 1e-4 * naive.abs().max(1.0),
                "naive={naive} fast={fast} d={d}"
            );
        });
    }

    #[test]
    fn dot_matches_naive() {
        check_property("dot-naive", 101, |rng| {
            let d = 1 + rng.gen_range(300);
            let a = rand_vec(rng, d);
            let b = rand_vec(rng, d);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot(&a, &b);
            assert!((naive - fast).abs() <= 1e-3 * naive.abs().max(1.0));
        });
    }

    #[test]
    fn l2_identity_and_symmetry() {
        check_property("l2-axioms", 102, |rng| {
            let d = 1 + rng.gen_range(64);
            let a = rand_vec(rng, d);
            let b = rand_vec(rng, d);
            assert_eq!(l2_sq(&a, &a), 0.0);
            assert!((l2_sq(&a, &b) - l2_sq(&b, &a)).abs() < 1e-5);
            assert!(l2_sq(&a, &b) >= 0.0);
        });
    }

    #[test]
    fn cosine_range_and_self() {
        let a = vec![1.0, 0.0];
        let b = vec![0.0, 1.0];
        let c = vec![-1.0, 0.0];
        assert!((cosine_dist(&a, &a)).abs() < 1e-6);
        assert!((cosine_dist(&a, &b) - 1.0).abs() < 1e-6);
        assert!((cosine_dist(&a, &c) - 2.0).abs() < 1e-6);
        assert_eq!(cosine_dist(&a, &[0.0, 0.0]), 1.0);
    }

    #[test]
    fn metric_dispatch() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        assert_eq!(Metric::L2.distance(&a, &b), 8.0);
        assert_eq!(Metric::InnerProduct.distance(&a, &b), -11.0);
        assert_eq!(Metric::from_name("L2"), Some(Metric::L2));
        assert_eq!(Metric::from_name("bogus"), None);
    }
}

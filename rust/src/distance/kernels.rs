//! Runtime-dispatched SIMD distance kernels.
//!
//! The crate's hot paths evaluate *one query against a contiguous block
//! of rows* (beam-search neighbor expansion, Local-Join candidate
//! blocks, SQ8 rerank candidates). [`one_to_many_l2`] is that
//! primitive; [`cross_l2`] tiles it into a full `nx x ny` block, and
//! [`one_to_many_l2_sq8`] is the asymmetric u8-code variant the
//! quantized resident tier searches over.
//!
//! # Dispatch
//!
//! The implementation is picked **once per process** (first call) via
//! `is_x86_feature_detected!`: AVX2+FMA when the CPU has both, the
//! portable scalar path otherwise — so a binary compiled for the
//! x86-64 baseline still uses 256-bit kernels on capable machines, and
//! non-x86 targets compile the scalar path only. `KNN_KERNEL=scalar`
//! in the environment forces the fallback (used by the equivalence
//! tests and the microbench's scalar reference rows).
//!
//! The scalar and SIMD paths accumulate in different orders, so they
//! agree to ~1e-6 relative, not bitwise; every consumer of these
//! kernels treats distances as approximate ranks (ties broken by id),
//! and the proptests in `rust/tests/kernel_quant.rs` pin the paths
//! together within 1e-5 relative tolerance.

use super::l2_sq;
use std::sync::OnceLock;

/// Which kernel implementation this process dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable Rust (8-wide unrolled `l2_sq` loops). Always available.
    Scalar,
    /// 256-bit AVX2 + FMA intrinsics (x86-64 with runtime detection).
    Avx2,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
        }
    }
}

static KIND: OnceLock<KernelKind> = OnceLock::new();

/// The kernel implementation selected for this process (detected once,
/// then cached). `KNN_KERNEL=scalar` forces the fallback.
pub fn kind() -> KernelKind {
    *KIND.get_or_init(detect)
}

/// Name of the dispatched kernel path (`"scalar"` or `"avx2"`), for
/// logs and bench rows.
pub fn kernel_name() -> &'static str {
    kind().name()
}

fn detect() -> KernelKind {
    if std::env::var("KNN_KERNEL").as_deref() == Ok("scalar") {
        return KernelKind::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return KernelKind::Avx2;
        }
    }
    KernelKind::Scalar
}

/// Y-tile rows of [`cross_l2`]: one tile of `ys` stays hot in L1/L2
/// while every `xs` row streams over it (32 rows x 128 dims x 4 B =
/// 16 KiB, half a typical L1d).
const CROSS_TILE_Y: usize = 32;

/// Squared L2 of `query` against each of the `out.len()` contiguous
/// `dim`-wide rows in `rows`, written to `out` in row order.
#[inline]
pub fn one_to_many_l2(query: &[f32], rows: &[f32], dim: usize, out: &mut [f32]) {
    debug_assert_eq!(query.len(), dim);
    debug_assert_eq!(rows.len(), out.len() * dim);
    if out.is_empty() {
        return;
    }
    match kind() {
        KernelKind::Scalar => one_to_many_l2_scalar(query, rows, dim, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `kind()` returned Avx2 only after
        // `is_x86_feature_detected!` confirmed AVX2 and FMA on this CPU.
        KernelKind::Avx2 => unsafe { avx2::one_to_many_l2(query, rows, dim, out) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelKind::Avx2 => unreachable!("avx2 kernels are x86_64-only"),
    }
}

/// Portable reference implementation of [`one_to_many_l2`] (also the
/// dispatch target on machines without AVX2). Public so benches and
/// equivalence tests can pin the SIMD path against it explicitly.
#[inline]
pub fn one_to_many_l2_scalar(query: &[f32], rows: &[f32], dim: usize, out: &mut [f32]) {
    for (r, o) in out.iter_mut().enumerate() {
        *o = l2_sq(query, &rows[r * dim..(r + 1) * dim]);
    }
}

/// Full `nx x ny` squared-L2 cross block between row-major `xs` and
/// `ys`, written row-major into `out`. Tiled over `ys` so each y-tile
/// is reused across every x row ([`CROSS_TILE_Y`]); each (row, tile)
/// pair runs through [`one_to_many_l2`].
pub fn cross_l2(xs: &[f32], ys: &[f32], dim: usize, nx: usize, ny: usize, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), nx * dim);
    debug_assert_eq!(ys.len(), ny * dim);
    debug_assert_eq!(out.len(), nx * ny);
    let mut j0 = 0;
    while j0 < ny {
        let t = CROSS_TILE_Y.min(ny - j0);
        let tile = &ys[j0 * dim..(j0 + t) * dim];
        for i in 0..nx {
            let x = &xs[i * dim..(i + 1) * dim];
            one_to_many_l2(x, tile, dim, &mut out[i * ny + j0..i * ny + j0 + t]);
        }
        j0 += t;
    }
}

/// Asymmetric squared L2 of an f32 `query` against `out.len()`
/// contiguous SQ8 rows: code `c` of dimension `d` decodes to
/// `mins[d] + c * scales[d]` (see `dataset::quant::SQ8Store`), and the
/// distance is computed against the decoded value without ever
/// materializing the f32 row.
#[inline]
pub fn one_to_many_l2_sq8(
    query: &[f32],
    codes: &[u8],
    mins: &[f32],
    scales: &[f32],
    dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(query.len(), dim);
    debug_assert_eq!(mins.len(), dim);
    debug_assert_eq!(scales.len(), dim);
    debug_assert_eq!(codes.len(), out.len() * dim);
    if out.is_empty() {
        return;
    }
    match kind() {
        KernelKind::Scalar => one_to_many_l2_sq8_scalar(query, codes, mins, scales, dim, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `kind()` returned Avx2 only after
        // `is_x86_feature_detected!` confirmed AVX2 and FMA on this CPU.
        KernelKind::Avx2 => unsafe {
            avx2::one_to_many_l2_sq8(query, codes, mins, scales, dim, out)
        },
        #[cfg(not(target_arch = "x86_64"))]
        KernelKind::Avx2 => unreachable!("avx2 kernels are x86_64-only"),
    }
}

/// Portable reference implementation of [`one_to_many_l2_sq8`].
#[inline]
pub fn one_to_many_l2_sq8_scalar(
    query: &[f32],
    codes: &[u8],
    mins: &[f32],
    scales: &[f32],
    dim: usize,
    out: &mut [f32],
) {
    for (r, o) in out.iter_mut().enumerate() {
        let row = &codes[r * dim..(r + 1) * dim];
        let mut acc = [0.0f32; 4];
        let mut d = 0;
        while d + 4 <= dim {
            for j in 0..4 {
                let dec = (row[d + j] as f32).mul_add(scales[d + j], mins[d + j]);
                let diff = query[d + j] - dec;
                acc[j] = diff.mul_add(diff, acc[j]);
            }
            d += 4;
        }
        let mut sum = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        while d < dim {
            let dec = (row[d] as f32).mul_add(scales[d], mins[d]);
            let diff = query[d] - dec;
            sum = diff.mul_add(diff, sum);
            d += 1;
        }
        *o = sum;
    }
}

/// AVX2 + FMA kernel bodies. Compiled on x86-64 only; every function
/// is `#[target_feature]`-gated and must only be reached through the
/// feature-detected dispatch in this module's public entry points.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m128i, __m256, _mm256_add_ps, _mm256_castps256_ps128, _mm256_cvtepi32_ps,
        _mm256_cvtepu8_epi32, _mm256_extractf128_ps, _mm256_fmadd_ps, _mm256_loadu_ps,
        _mm256_setzero_ps, _mm256_sub_ps, _mm_add_ps, _mm_add_ss, _mm_cvtss_f32,
        _mm_loadl_epi64, _mm_movehdup_ps, _mm_movehl_ps,
    };

    /// Horizontal sum of the 8 lanes of `v`.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available (enforced by the
    /// feature-detected dispatch in the parent module).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        // SAFETY: pure register arithmetic, no memory access; AVX2 is
        // guaranteed by this function's target_feature contract.
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let shuf = _mm_movehdup_ps(s);
        let sums = _mm_add_ps(s, shuf);
        let hi2 = _mm_movehl_ps(shuf, sums);
        _mm_cvtss_f32(_mm_add_ss(sums, hi2))
    }

    /// AVX2 body of [`super::one_to_many_l2`].
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available; slice lengths
    /// must satisfy `query.len() == dim` and
    /// `rows.len() == out.len() * dim` (debug-asserted by the caller).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn one_to_many_l2(query: &[f32], rows: &[f32], dim: usize, out: &mut [f32]) {
        let q = query.as_ptr();
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: r < out.len() and rows holds out.len() * dim
            // floats, so the row pointer and every in-row offset below
            // stay inside `rows`; the `d + 16 <= dim` / `d + 8 <= dim`
            // guards keep each 8-lane load of q and row in bounds.
            let row = rows.as_ptr().add(r * dim);
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut d = 0usize;
            while d + 16 <= dim {
                let da = _mm256_sub_ps(_mm256_loadu_ps(q.add(d)), _mm256_loadu_ps(row.add(d)));
                acc0 = _mm256_fmadd_ps(da, da, acc0);
                let db = _mm256_sub_ps(
                    _mm256_loadu_ps(q.add(d + 8)),
                    _mm256_loadu_ps(row.add(d + 8)),
                );
                acc1 = _mm256_fmadd_ps(db, db, acc1);
                d += 16;
            }
            while d + 8 <= dim {
                let da = _mm256_sub_ps(_mm256_loadu_ps(q.add(d)), _mm256_loadu_ps(row.add(d)));
                acc0 = _mm256_fmadd_ps(da, da, acc0);
                d += 8;
            }
            let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
            while d < dim {
                // SAFETY: d < dim, inside both the query and the row.
                let diff = *q.add(d) - *row.add(d);
                sum = diff.mul_add(diff, sum);
                d += 1;
            }
            *o = sum;
        }
    }

    /// AVX2 body of [`super::one_to_many_l2_sq8`]: u8 codes widen to
    /// f32 in-register (`cvtepu8_epi32` + `cvtepi32_ps`), decode via
    /// one FMA against the per-dimension affine, then the usual
    /// sub/FMA accumulation — no decoded row is ever written to
    /// memory.
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available; slice lengths
    /// must satisfy `query.len() == mins.len() == scales.len() == dim`
    /// and `codes.len() == out.len() * dim`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn one_to_many_l2_sq8(
        query: &[f32],
        codes: &[u8],
        mins: &[f32],
        scales: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let q = query.as_ptr();
        let mn = mins.as_ptr();
        let sc = scales.as_ptr();
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: r < out.len() and codes holds out.len() * dim
            // bytes; the `d + 8 <= dim` guard keeps the 8-byte code
            // load and every 8-lane f32 load below in bounds.
            let row = codes.as_ptr().add(r * dim);
            let mut acc = _mm256_setzero_ps();
            let mut d = 0usize;
            while d + 8 <= dim {
                let c8 = _mm_loadl_epi64(row.add(d) as *const __m128i);
                let cf = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(c8));
                let dec = _mm256_fmadd_ps(cf, _mm256_loadu_ps(sc.add(d)), _mm256_loadu_ps(mn.add(d)));
                let diff = _mm256_sub_ps(_mm256_loadu_ps(q.add(d)), dec);
                acc = _mm256_fmadd_ps(diff, diff, acc);
                d += 8;
            }
            let mut sum = hsum256(acc);
            while d < dim {
                // SAFETY: d < dim, inside the codes row and the f32
                // parameter slices.
                let dec = (*row.add(d) as f32).mul_add(*sc.add(d), *mn.add(d));
                let diff = *q.add(d) - dec;
                sum = diff.mul_add(diff, sum);
                d += 1;
            }
            *o = sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_property;

    fn rand_block(rng: &mut crate::util::Rng, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.gen_normal()).collect()
    }

    #[test]
    fn dispatch_is_stable_and_named() {
        let k = kind();
        assert_eq!(k, kind(), "kind() must cache its first answer");
        assert!(matches!(kernel_name(), "scalar" | "avx2"));
    }

    #[test]
    fn one_to_many_matches_per_pair_l2() {
        check_property("one-to-many-l2", 210, |rng| {
            // Odd dims on purpose: 1, 3, 7 and non-multiples of the
            // 8/16 lane widths exercise every tail path.
            let dims = [1usize, 3, 7, 8, 15, 16, 17, 31, 64, 100, 128];
            let d = dims[rng.gen_range(dims.len())];
            let n = rng.gen_range(9); // includes 0 (empty block)
            let q = rand_block(rng, 1, d);
            let rows = rand_block(rng, n, d);
            let mut out = vec![f32::NAN; n];
            one_to_many_l2(&q, &rows, d, &mut out);
            for r in 0..n {
                let expect = l2_sq(&q, &rows[r * d..(r + 1) * d]);
                assert!(
                    (out[r] - expect).abs() <= 1e-5 * expect.abs().max(1.0),
                    "d={d} r={r}: kernel={} l2_sq={expect}",
                    out[r]
                );
            }
        });
    }

    #[test]
    fn scalar_path_is_exactly_per_pair_l2() {
        check_property("one-to-many-scalar", 211, |rng| {
            let d = 1 + rng.gen_range(96);
            let n = 1 + rng.gen_range(6);
            let q = rand_block(rng, 1, d);
            let rows = rand_block(rng, n, d);
            let mut out = vec![0.0; n];
            one_to_many_l2_scalar(&q, &rows, d, &mut out);
            for r in 0..n {
                assert_eq!(out[r], l2_sq(&q, &rows[r * d..(r + 1) * d]));
            }
        });
    }

    #[test]
    fn cross_matches_one_to_many_rows() {
        check_property("cross-l2-tiled", 212, |rng| {
            let d = 1 + rng.gen_range(80);
            let nx = 1 + rng.gen_range(7);
            // Straddle the y tile boundary so the tiling itself is hit.
            let ny = 1 + rng.gen_range(2 * CROSS_TILE_Y);
            let xs = rand_block(rng, nx, d);
            let ys = rand_block(rng, ny, d);
            let mut out = vec![f32::NAN; nx * ny];
            cross_l2(&xs, &ys, d, nx, ny, &mut out);
            for i in 0..nx {
                let mut row = vec![0.0; ny];
                one_to_many_l2(&xs[i * d..(i + 1) * d], &ys, d, &mut row);
                for j in 0..ny {
                    let got = out[i * ny + j];
                    assert!(
                        (got - row[j]).abs() <= 1e-5 * row[j].abs().max(1.0),
                        "({i},{j}): tiled={got} flat={}",
                        row[j]
                    );
                }
            }
        });
    }

    #[test]
    fn sq8_kernel_matches_scalar_reference() {
        check_property("sq8-kernel", 213, |rng| {
            let dims = [1usize, 3, 7, 8, 13, 16, 33, 64, 128];
            let d = dims[rng.gen_range(dims.len())];
            let n = rng.gen_range(7);
            let q = rand_block(rng, 1, d);
            let codes: Vec<u8> = (0..n * d).map(|_| rng.gen_range(256) as u8).collect();
            let mins: Vec<f32> = (0..d).map(|_| rng.gen_normal()).collect();
            let scales: Vec<f32> = (0..d).map(|_| rng.gen_f32() * 0.02).collect();
            let mut a = vec![f32::NAN; n];
            let mut b = vec![f32::NAN; n];
            one_to_many_l2_sq8(&q, &codes, &mins, &scales, d, &mut a);
            one_to_many_l2_sq8_scalar(&q, &codes, &mins, &scales, d, &mut b);
            for r in 0..n {
                assert!(
                    (a[r] - b[r]).abs() <= 1e-5 * b[r].abs().max(1.0),
                    "d={d} r={r}: dispatched={} scalar={}",
                    a[r],
                    b[r]
                );
            }
        });
    }
}

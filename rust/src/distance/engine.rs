//! Batched distance engines.
//!
//! The Local-Join step of the merge algorithms evaluates *blocks* of
//! pairwise distances (every sampled `u` against every sampled `v` of a
//! neighborhood, across many neighborhoods). [`DistanceEngine`] abstracts
//! where those blocks are computed:
//!
//! - [`ScalarEngine`] — tight unrolled loops on the CPU (always available).
//! - `runtime::XlaEngine` — the AOT-lowered Pallas kernel executed via
//!   PJRT; profitable for large blocks where the fixed PJRT dispatch cost
//!   amortizes (see `benches/microbench.rs` for the crossover).

use super::l2_sq;

/// A batched cross-distance evaluator. All distances are **squared L2**
/// (the monotone form used throughout the crate).
pub trait DistanceEngine: Send + Sync {
    /// Human-readable engine name (for logs and bench rows).
    fn name(&self) -> &'static str;

    /// Compute the full `nx x ny` cross-distance matrix between the
    /// row-major blocks `xs` (`nx * dim`) and `ys` (`ny * dim`), writing
    /// row-major results into `out` (`nx * ny`).
    fn cross_l2(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
    );

    /// Convenience wrapper allocating the output.
    fn cross_l2_alloc(&self, xs: &[f32], ys: &[f32], dim: usize, nx: usize, ny: usize) -> Vec<f32> {
        let mut out = vec![0.0; nx * ny];
        self.cross_l2(xs, ys, dim, nx, ny, &mut out);
        out
    }

    /// Whether Local-Join should accumulate blocks and dispatch them in
    /// batches through [`DistanceEngine::batch_cross_l2`] (true for
    /// dispatch-cost engines like the PJRT path) instead of per-pair
    /// scalar evaluation.
    fn prefers_batches(&self) -> bool {
        false
    }

    /// Tile shape `(nx, ny)` the engine's batched path is compiled for.
    /// [`crate::merge::join::BatchJoiner`] splits/pads blocks to this.
    fn batch_tile(&self) -> (usize, usize) {
        (32, 32)
    }

    /// Batched form: `b` independent `nx x ny` blocks. `xs` is
    /// `b * nx * dim`, `ys` is `b * ny * dim`, `out` is `b * nx * ny`.
    /// Default loops over [`DistanceEngine::cross_l2`]; engines with
    /// dispatch overhead override with a single fused call.
    fn batch_cross_l2(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        b: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(xs.len(), b * nx * dim);
        debug_assert_eq!(ys.len(), b * ny * dim);
        debug_assert_eq!(out.len(), b * nx * ny);
        for t in 0..b {
            self.cross_l2(
                &xs[t * nx * dim..(t + 1) * nx * dim],
                &ys[t * ny * dim..(t + 1) * ny * dim],
                dim,
                nx,
                ny,
                &mut out[t * nx * ny..(t + 1) * nx * ny],
            );
        }
    }
}

/// Pure-Rust engine, routed through the runtime-dispatched tiled
/// kernel ([`super::kernels::cross_l2`]): AVX2/FMA where the CPU has
/// it, the unrolled scalar loop elsewhere. For the small, ragged
/// blocks Local-Join mostly produces this beats any dispatch-based path.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarEngine;

impl DistanceEngine for ScalarEngine {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn cross_l2(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(xs.len(), nx * dim);
        debug_assert_eq!(ys.len(), ny * dim);
        debug_assert_eq!(out.len(), nx * ny);
        super::kernels::cross_l2(xs, ys, dim, nx, ny, out);
    }
}

/// Reusable norm buffers for [`NormExpandEngine`]. Local-Join calls
/// `cross_l2` once per candidate block; without caller-provided
/// scratch every call re-allocated both norm vectors.
#[derive(Clone, Debug, Default)]
pub struct NormScratch {
    xn: Vec<f32>,
    yn: Vec<f32>,
}

/// Y-tile width of the norm-expansion inner loop: one tile of `ys`
/// (and its norms) stays hot while every `xs` row streams over it.
const NORM_TILE_Y: usize = 32;

/// Norm-expansion engine: computes `||x||^2 + ||y||^2 - 2 x.y` with a
/// blocked matmul-style inner loop — the same formulation the Pallas
/// kernel uses, kept here as (a) a CPU reference for the XLA path and
/// (b) the faster choice for large dense blocks.
#[derive(Clone, Copy, Debug, Default)]
pub struct NormExpandEngine;

impl NormExpandEngine {
    /// [`DistanceEngine::cross_l2`] with caller-provided scratch: the
    /// norm vectors live in `scratch` (cleared, not re-allocated, per
    /// call) and the inner loop is tiled over `ys` so each y-tile and
    /// its norms are reused across every `xs` row.
    pub fn cross_l2_with(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
        scratch: &mut NormScratch,
    ) {
        debug_assert_eq!(xs.len(), nx * dim);
        debug_assert_eq!(ys.len(), ny * dim);
        debug_assert_eq!(out.len(), nx * ny);
        scratch.xn.clear();
        scratch.yn.clear();
        scratch
            .xn
            .extend((0..nx).map(|i| super::dot(&xs[i * dim..(i + 1) * dim], &xs[i * dim..(i + 1) * dim])));
        scratch
            .yn
            .extend((0..ny).map(|j| super::dot(&ys[j * dim..(j + 1) * dim], &ys[j * dim..(j + 1) * dim])));
        let mut j0 = 0;
        while j0 < ny {
            let t = NORM_TILE_Y.min(ny - j0);
            for i in 0..nx {
                let x = &xs[i * dim..(i + 1) * dim];
                let row = &mut out[i * ny + j0..i * ny + j0 + t];
                for (jt, o) in row.iter_mut().enumerate() {
                    let j = j0 + jt;
                    let d = scratch.xn[i] + scratch.yn[j]
                        - 2.0 * super::dot(x, &ys[j * dim..(j + 1) * dim]);
                    // Clamp tiny negatives from cancellation.
                    *o = d.max(0.0);
                }
            }
            j0 += t;
        }
    }
}

impl DistanceEngine for NormExpandEngine {
    fn name(&self) -> &'static str {
        "norm-expand"
    }

    fn cross_l2(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
    ) {
        let mut scratch = NormScratch::default();
        self.cross_l2_with(xs, ys, dim, nx, ny, out, &mut scratch);
    }

    fn batch_cross_l2(
        &self,
        xs: &[f32],
        ys: &[f32],
        dim: usize,
        b: usize,
        nx: usize,
        ny: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(xs.len(), b * nx * dim);
        debug_assert_eq!(ys.len(), b * ny * dim);
        debug_assert_eq!(out.len(), b * nx * ny);
        // One scratch for the whole batch — the per-call allocation the
        // default per-block loop would pay b times.
        let mut scratch = NormScratch::default();
        for t in 0..b {
            self.cross_l2_with(
                &xs[t * nx * dim..(t + 1) * nx * dim],
                &ys[t * ny * dim..(t + 1) * ny * dim],
                dim,
                nx,
                ny,
                &mut out[t * nx * ny..(t + 1) * nx * ny],
                &mut scratch,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_property;

    fn rand_block(rng: &mut crate::util::Rng, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.gen_normal()).collect()
    }

    #[test]
    fn scalar_engine_matches_pointwise() {
        check_property("scalar-engine", 200, |rng| {
            let d = 1 + rng.gen_range(64);
            let nx = 1 + rng.gen_range(8);
            let ny = 1 + rng.gen_range(8);
            let xs = rand_block(rng, nx, d);
            let ys = rand_block(rng, ny, d);
            let out = ScalarEngine.cross_l2_alloc(&xs, &ys, d, nx, ny);
            for i in 0..nx {
                for j in 0..ny {
                    let expect = l2_sq(&xs[i * d..(i + 1) * d], &ys[j * d..(j + 1) * d]);
                    let got = out[i * ny + j];
                    // The engine dispatches to the SIMD kernel when the
                    // CPU has AVX2; summation order differs from l2_sq,
                    // so equality is relative, not bitwise.
                    assert!(
                        (got - expect).abs() <= 1e-5 * expect.abs().max(1.0),
                        "({i},{j}): engine={got} l2_sq={expect}"
                    );
                }
            }
        });
    }

    #[test]
    fn norm_scratch_reuse_matches_fresh() {
        check_property("norm-scratch", 203, |rng| {
            let d = 1 + rng.gen_range(48);
            let mut scratch = NormScratch::default();
            // Two blocks of different shapes through the same scratch:
            // stale norms from the first call must not leak into the
            // second.
            for _ in 0..2 {
                let nx = 1 + rng.gen_range(40);
                let ny = 1 + rng.gen_range(40);
                let xs = rand_block(rng, nx, d);
                let ys = rand_block(rng, ny, d);
                let mut reused = vec![0.0; nx * ny];
                NormExpandEngine.cross_l2_with(&xs, &ys, d, nx, ny, &mut reused, &mut scratch);
                let fresh = NormExpandEngine.cross_l2_alloc(&xs, &ys, d, nx, ny);
                assert_eq!(reused, fresh);
            }
        });
    }

    #[test]
    fn batch_default_matches_per_block() {
        check_property("batch-default", 202, |rng| {
            let d = 1 + rng.gen_range(32);
            let b = 1 + rng.gen_range(4);
            let nx = 1 + rng.gen_range(6);
            let ny = 1 + rng.gen_range(6);
            let xs = rand_block(rng, b * nx, d);
            let ys = rand_block(rng, b * ny, d);
            let mut out = vec![0.0; b * nx * ny];
            ScalarEngine.batch_cross_l2(&xs, &ys, d, b, nx, ny, &mut out);
            for t in 0..b {
                let expect = ScalarEngine.cross_l2_alloc(
                    &xs[t * nx * d..(t + 1) * nx * d],
                    &ys[t * ny * d..(t + 1) * ny * d],
                    d,
                    nx,
                    ny,
                );
                assert_eq!(&out[t * nx * ny..(t + 1) * nx * ny], &expect[..]);
            }
        });
    }

    #[test]
    fn norm_expand_matches_scalar() {
        check_property("norm-expand", 201, |rng| {
            let d = 1 + rng.gen_range(128);
            let nx = 1 + rng.gen_range(16);
            let ny = 1 + rng.gen_range(16);
            let xs = rand_block(rng, nx, d);
            let ys = rand_block(rng, ny, d);
            let a = ScalarEngine.cross_l2_alloc(&xs, &ys, d, nx, ny);
            let b = NormExpandEngine.cross_l2_alloc(&xs, &ys, d, nx, ny);
            for (x, y) in a.iter().zip(&b) {
                assert!(
                    (x - y).abs() <= 1e-3 * x.abs().max(1.0),
                    "scalar={x} expand={y}"
                );
            }
        });
    }
}

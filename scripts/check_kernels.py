#!/usr/bin/env python3
"""Validate results/kernels.json from the `kernels` bench (ISSUE 7).

Checks:
- the four kernel rows exist (`kernel_{scalar,simd}_{d32,d128}`) with
  positive throughput;
- when dispatch selected a SIMD path (`simd == 1` on the simd rows),
  the dispatched kernel is >= 2x the scalar reference at d128;
  on scalar-only machines the speedup gate is skipped with a note
  (equivalence is covered by the proptests instead);
- the `sq8_probe` row exists, its quantized recall is within 0.01 of
  full precision, and the resident-bytes ratio is >= 3.5 (the SQ8 tier
  replaces 4-byte floats with 1-byte codes plus per-dim params).

Usage: check_kernels.py <kernels.json>
"""

import json
import sys

SPEEDUP_FLOOR = 2.0
RECALL_SLACK = 0.01
RATIO_FLOOR = 3.5

ERRORS = []


def err(msg):
    ERRORS.append(msg)


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL {path}: unreadable or invalid JSON: {e}", file=sys.stderr)
        return 1

    rows = {r.get("label"): r for r in report.get("rows", [])}
    notes = []

    for dim in (32, 128):
        for variant in ("scalar", "simd"):
            label = f"kernel_{variant}_d{dim}"
            row = rows.get(label)
            if row is None:
                err(f"missing row {label!r}")
                continue
            if row.get("Mpairs/s", 0) <= 0:
                err(f"{label}: Mpairs/s must be > 0")

    simd_row = rows.get("kernel_simd_d128")
    scalar_row = rows.get("kernel_scalar_d128")
    if simd_row and scalar_row:
        if simd_row.get("simd") == 1:
            speedup = simd_row.get("Mpairs/s", 0) / max(scalar_row.get("Mpairs/s", 1e-9), 1e-9)
            if speedup < SPEEDUP_FLOOR:
                err(f"kernel_simd_d128: {speedup:.2f}x over scalar, need >= {SPEEDUP_FLOOR}x")
            else:
                notes.append(f"simd d128 speedup {speedup:.2f}x")
        else:
            notes.append("scalar-only dispatch (no AVX2 or KNN_KERNEL=scalar); speedup gate skipped")

    probe = rows.get("sq8_probe")
    if probe is None:
        err("missing row 'sq8_probe'")
    else:
        full, sq8 = probe.get("recall_full"), probe.get("recall_sq8")
        if full is None or sq8 is None:
            err("sq8_probe: missing recall_full/recall_sq8")
        elif sq8 < full - RECALL_SLACK:
            err(f"sq8_probe: quantized recall {sq8:.4f} below full {full:.4f} - {RECALL_SLACK}")
        else:
            notes.append(f"recall full={full:.4f} sq8={sq8:.4f}")
        ratio = probe.get("resident_ratio", 0)
        if ratio < RATIO_FLOOR:
            err(f"sq8_probe: resident_ratio {ratio:.2f} below {RATIO_FLOOR}")
        if probe.get("rerank_rows_per_query", 0) <= 0:
            err("sq8_probe: rerank_rows_per_query must be > 0 (rerank never ran)")

    if ERRORS:
        print(f"FAIL {path}: {len(ERRORS)} problem(s)", file=sys.stderr)
        for e in ERRORS:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"OK {path}: kernels report valid ({'; '.join(notes)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

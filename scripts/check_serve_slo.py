#!/usr/bin/env python3
"""Validate results/serve_slo.json from the serve_slo bench.

Used by scripts/verify.sh as the serve smoke: after the mixed-workload
run over a live KSRV TCP server, the report must carry one quantile row
per request class (search/insert/delete/upsert, each with server-side
p50/p95/p99 and a sample count) plus the degradation-drill row proving
admission control fired: ingest was shed (Overloaded), searches kept
answering, and their beams degraded.

Usage: check_serve_slo.py <serve_slo.json>
"""

import json
import sys

ERRORS = []

CLASS_LABELS = ["search", "insert", "delete", "upsert"]
QUANTILE_KEYS = ["p50_ms", "p95_ms", "p99_ms"]
DRILL_KEYS = ["ops", "rejected", "shed_seen_by_clients",
              "searches_answered", "degraded_searches", "search_p99_ms"]


def err(msg):
    ERRORS.append(msg)


def check_class_row(row, label):
    if row.get("count", 0) <= 0:
        err(f"{label}: count must be > 0, got {row.get('count')}")
    for key in QUANTILE_KEYS:
        if not isinstance(row.get(key), (int, float)):
            err(f"{label}: missing quantile column {key!r}")
            return
        if row[key] < 0:
            err(f"{label}: {key} is negative ({row[key]})")
    if row.get("p50_ms", 0) > row.get("p99_ms", 0):
        err(f"{label}: p50 {row.get('p50_ms')} > p99 {row.get('p99_ms')}")


def check_drill_row(row):
    for key in DRILL_KEYS:
        if not isinstance(row.get(key), (int, float)):
            err(f"drill: missing column {key!r}")
    if row.get("rejected", 0) < 1:
        err(f"drill: no ingest was shed (rejected={row.get('rejected')}) — "
            f"the overload drill did not fire")
    if row.get("shed_seen_by_clients", 0) < 1:
        err("drill: no client observed an Overloaded response")
    if row.get("searches_answered", 0) < 1:
        err("drill: no search answered while ingest was shed — searches "
            "must never be rejected")
    if row.get("degraded_searches", 0) < 1:
        err(f"drill: no search degraded "
            f"(degraded_searches={row.get('degraded_searches')}) — the "
            f"over-committed search class must degrade toward topk")


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL {path}: unreadable or invalid JSON: {e}", file=sys.stderr)
        return 1

    if report.get("name") != "serve_slo":
        err(f"name must be 'serve_slo', got {report.get('name')!r}")
    rows = report.get("rows")
    if not isinstance(rows, list):
        err("rows: missing or not a list")
        rows = []
    by_label = {}
    for row in rows:
        if isinstance(row, dict) and isinstance(row.get("label"), str):
            by_label[row["label"]] = row

    for label in CLASS_LABELS:
        if label not in by_label:
            err(f"rows: missing per-class row {label!r}")
        else:
            check_class_row(by_label[label], label)
    if "drill" not in by_label:
        err("rows: missing the 'drill' row")
    else:
        check_drill_row(by_label["drill"])

    if ERRORS:
        print(f"FAIL {path}: {len(ERRORS)} problem(s)", file=sys.stderr)
        for e in ERRORS:
            print(f"  - {e}", file=sys.stderr)
        return 1
    drill = by_label["drill"]
    print(f"OK {path}: {len(CLASS_LABELS)} class rows + drill "
          f"(rejected={drill['rejected']:.0f}, "
          f"searches_answered={drill['searches_answered']:.0f}, "
          f"degraded={drill['degraded_searches']:.0f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Compatibility shim: the static sweep now lives in scripts/knnlint/.

Everything this script used to do (delimiter balance, mod-tree checks,
import resolution, Cargo target paths, fixture references, SIMD
hygiene) migrated into the `structure`, `spans`, and `simd` rule
modules of the knnlint package, which adds lock-order checking,
panic-path auditing, invariant coupling, a findings baseline, and
`--json` output on top.

    python3 scripts/knnlint --help

This entry point stays so existing muscle memory and docs keep
working; it just execs the package CLI with the same arguments.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from knnlint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Static sanity sweep for containers without a Rust toolchain.

Not a compiler — a tripwire for the error classes that have actually
bitten written-but-not-compiled PRs in this repo:

  1. delimiter balance per file (strings/chars/comments stripped),
  2. `mod` declarations vs. files on disk (both directions),
  3. `use crate::…` / `use knn_merge::…` path resolution against the
     declared module tree and each module's `pub` item surface,
  4. `pub use` re-export resolution,
  5. Cargo.toml target paths exist,
  6. every committed fixture under rust/tests/data/ is referenced by
     name in at least one rust/tests/*.rs file (orphaned golden files
     mean a test stopped guarding a wire format),
  7. SIMD hygiene: in files using std::arch/core::arch, every `unsafe`
     must carry a nearby `// SAFETY:` comment, and `#[target_feature]`
     functions must sit behind a `cfg(target_arch = ...)` gate.

Exit code 0 = no findings. Anything found prints `FILE:LINE: message`
and exits 1. Run from anywhere: paths resolve relative to the repo
root (parent of scripts/).
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
RUST = ROOT / "rust" / "src"

findings: list[str] = []


def report(path, line, msg):
    findings.append(f"{path.relative_to(ROOT)}:{line}: {msg}")


# ---------------------------------------------------------------- strip


def strip_rust(text: str) -> str:
    """Remove string/char literals and comments, preserving newlines."""
    out = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        two = text[i : i + 2]
        if two == "//":
            j = text.find("\n", i)
            i = n if j < 0 else j
        elif two == "/*":
            depth, i = 1, i + 2
            while i < n and depth:
                if text[i : i + 2] == "/*":
                    depth, i = depth + 1, i + 2
                elif text[i : i + 2] == "*/":
                    depth, i = depth - 1, i + 2
                else:
                    if text[i] == "\n":
                        out.append("\n")
                    i += 1
        elif c == '"' or two == 'r"' or re.match(r'r#+"', text[i : i + 8] or ""):
            if c == "r" or two == 'r"':
                m = re.match(r'r(#*)"', text[i:])
                hashes = m.group(1)
                end = text.find('"' + hashes, i + len(m.group(0)))
                seg = text[i : end + 1 + len(hashes)] if end >= 0 else text[i:]
                out.append("\n" * seg.count("\n"))
                i = n if end < 0 else end + 1 + len(hashes)
            else:
                j = i + 1
                while j < n and text[j] != '"':
                    j += 2 if text[j] == "\\" else 1
                out.append("\n" * text[i:j].count("\n"))
                i = j + 1
        elif c == "'":
            # char literal or lifetime; char is 'x' or '\x' (escape)
            if i + 1 < n and text[i + 1] == "\\":
                j = text.find("'", i + 2)
                i = i + 2 if j < 0 else j + 1
            elif i + 2 < n and text[i + 2] == "'":
                i += 3
            else:  # lifetime — keep the tick out, skip the ident
                i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


# ---------------------------------------------------------- 1. balance

rust_files = sorted(RUST.rglob("*.rs")) + sorted(
    (ROOT / "rust").glob("tests/*.rs")
) + sorted((ROOT / "rust").glob("benches/*.rs")) + sorted(
    ROOT.glob("examples/*.rs")
)

stripped_cache: dict[Path, str] = {}
for f in rust_files:
    text = stripped_cache[f] = strip_rust(f.read_text())
    stack = []
    pairs = {")": "(", "]": "[", "}": "{"}
    line = 1
    for ch in text:
        if ch == "\n":
            line += 1
        elif ch in "([{":
            stack.append((ch, line))
        elif ch in ")]}":
            if not stack or stack[-1][0] != pairs[ch]:
                report(f, line, f"unbalanced '{ch}'")
                stack = []
                break
            stack.pop()
    if stack:
        report(f, stack[-1][1], f"unclosed '{stack[-1][0]}'")

# --------------------------------------------- 2. module tree coverage

mod_tree: dict[str, Path] = {"": RUST / "lib.rs"}


def walk(dir_path: Path, prefix: str, decl_file: Path):
    text = stripped_cache.get(decl_file) or strip_rust(decl_file.read_text())
    for m in re.finditer(r"^\s*(?:pub\s+)?mod\s+(\w+)\s*;", text, re.M):
        name = m.group(1)
        cand = [dir_path / f"{name}.rs", dir_path / name / "mod.rs"]
        hit = next((c for c in cand if c.exists()), None)
        if hit is None:
            report(decl_file, text[: m.start()].count("\n") + 1,
                   f"mod {name}: no file {cand[0].name} or {name}/mod.rs")
            continue
        key = f"{prefix}{name}"
        mod_tree[key] = hit
        walk(hit.parent if hit.name == "mod.rs" else dir_path / name,
             key + "::", hit)


walk(RUST, "", RUST / "lib.rs")

declared_files = set(mod_tree.values())
for f in sorted(RUST.rglob("*.rs")):
    if f.name in ("lib.rs", "main.rs"):
        continue
    if f not in declared_files:
        report(f, 1, "file exists but is not declared by any `mod`")

# ----------------------------------- 3. public item surface per module

ITEM_RE = re.compile(
    r"^\s*pub(?:\s*\(.*?\))?\s+"
    r"(?:unsafe\s+)?(?:async\s+)?"
    r"(?:struct|enum|trait|fn|type|const|static|mod|union)\s+"
    r"(\w+)",
    re.M,
)
USE_DECL_RE = re.compile(r"^\s*(?:pub\s+)?use\s+([^;]+);", re.M)

surface: dict[str, set[str]] = {}
for key, path in mod_tree.items():
    text = stripped_cache.get(path) or strip_rust(path.read_text())
    items = set(ITEM_RE.findall(text))
    # macro_rules! exports and re-exports land in the surface too
    items |= set(re.findall(r"macro_rules!\s*(\w+)", text))
    surface[key] = items


def expand_use(clause: str) -> list[str]:
    """`a::{b, c::d}` -> ['a::b', 'a::c::d'] (handles nesting, `as`)."""
    clause = clause.strip()
    m = re.match(r"^(.*?)\{(.*)\}$", clause, re.S)
    if not m:
        return [re.sub(r"\s+as\s+\w+$", "", clause).strip()]
    head, body = m.group(1), m.group(2)
    parts, depth, cur = [], 0, ""
    for ch in body:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    out = []
    for p in parts:
        out.extend(expand_use(head + p.strip()))
    return out


def resolve(path_str: str) -> bool:
    """True when `crate::a::b::Item` resolves against the module tree.

    A path resolves when its module prefix exists and the leaf is a
    declared item, a re-export, a submodule, `self`, or `*`.
    """
    segs = [s.strip() for s in path_str.split("::")]
    segs = [s for s in segs if s]
    if not segs:
        return True
    leaf = segs[-1]
    mods = segs[:-1]
    mod_key = "::".join(mods)
    if mod_key not in mod_tree:
        return False
    if leaf in ("self", "*"):
        return True
    if "::".join(segs) in mod_tree:  # leaf is itself a module
        return True
    if leaf in surface.get(mod_key, set()):
        return True
    # re-exports: `pub use x::y::Leaf;` inside the module
    text = stripped_cache.get(mod_tree[mod_key]) or ""
    for use in USE_DECL_RE.findall(text):
        for full in expand_use(use):
            if full.split("::")[-1] == leaf or full.endswith("::*"):
                return True
    return False


for f in rust_files:
    text = stripped_cache.get(f) or strip_rust(f.read_text())
    for m in USE_DECL_RE.finditer(text):
        for full in expand_use(m.group(1)):
            full = full.strip()
            if full.startswith("crate::"):
                rel = full[len("crate::"):]
            elif full.startswith("knn_merge::"):
                rel = full[len("knn_merge::"):]
            elif full.startswith("super::") or full.startswith("self::"):
                continue  # needs position context; compiler territory
            else:
                continue  # std / external crates
            if not resolve(rel):
                report(f, text[: m.start()].count("\n") + 1,
                       f"unresolved import `{full}`")

# -------------------------------------------- 4. Cargo target paths

cargo = (ROOT / "Cargo.toml").read_text()
for m in re.finditer(r'path\s*=\s*"([^"]+)"', cargo):
    if not (ROOT / m.group(1)).exists():
        report(ROOT / "Cargo.toml", cargo[: m.start()].count("\n") + 1,
               f"target path {m.group(1)} does not exist")

# ----------------------------------- 5. test fixtures are referenced

FIXTURE_DIR = ROOT / "rust" / "tests" / "data"
if FIXTURE_DIR.is_dir():
    # Raw test sources (NOT stripped: fixture names live in string
    # literals, which strip_rust removes).
    test_texts = [p.read_text() for p in sorted((ROOT / "rust" / "tests").glob("*.rs"))]
    for fx in sorted(FIXTURE_DIR.iterdir()):
        if fx.is_file() and not any(fx.name in t for t in test_texts):
            report(fx, 1, "fixture is not referenced by any rust/tests/*.rs test")

# ------------------------------ 6. Span guards are RAII, never manual

# A `Span::enter` whose guard is not bound to a variable is dropped at
# the end of the statement — it times nothing. `let _ =` is the same
# bug spelled differently (`_` drops immediately; `_span` does not),
# and a manual `Span::exit` API must never grow back: unwinds would
# skip it and corrupt the nesting stack.
SPAN_ENTER_RE = re.compile(r"Span\s*::\s*enter(?:_billed)?\b")
SPAN_BARE_RE = re.compile(r"^\s*(?:crate::metrics::|metrics::)?Span\s*::\s*enter")
SPAN_WILD_RE = re.compile(r"let\s+_\s*=")
for f in rust_files:
    text = stripped_cache.get(f) or strip_rust(f.read_text())
    for lineno, line in enumerate(text.split("\n"), 1):
        if re.search(r"Span\s*::\s*exit\b", line):
            report(f, lineno, "Span::exit: spans are RAII-only, use the guard")
        if not SPAN_ENTER_RE.search(line):
            continue
        if SPAN_BARE_RE.match(line):
            report(f, lineno,
                   "Span::enter guard dropped immediately — bind it: "
                   "`let _span = Span::enter(...)`")
        elif SPAN_WILD_RE.search(line.split("Span")[0]):
            report(f, lineno,
                   "`let _ = Span::enter(...)` drops the guard at once — "
                   "name it `_span`")

# ----------------------------- 7. SIMD unsafe is gated and documented

# Intrinsics are the one place this repo allows `unsafe`. Two rules for
# any file that touches std::arch / core::arch (checked on RAW text —
# the SAFETY comments rule 7 wants are exactly what strip_rust drops):
#  - every `unsafe` fn/block carries a `// SAFETY:` comment (or, for
#    `unsafe fn` declarations, a `/// # Safety` doc section) on the
#    same line or in the contiguous comment/attribute block above it,
#    so the contract (feature detection, slice bounds) is written down;
#  - every `#[target_feature(...)]` fn lives behind a
#    `cfg(target_arch = ...)` gate earlier in the file, so the crate
#    still compiles (scalar-only) on other architectures.
SAFETY_WINDOW = 4
for f in rust_files:
    raw = f.read_text()
    if "std::arch" not in raw and "core::arch" not in raw:
        continue
    lines = raw.split("\n")
    has_arch_gate = False
    for lineno, line in enumerate(lines, 1):
        if re.search(r"cfg\s*\(\s*target_arch", line):
            has_arch_gate = True
        if re.search(r"#\[target_feature", line) and not has_arch_gate:
            report(f, lineno,
                   "#[target_feature] with no cfg(target_arch=...) gate "
                   "earlier in the file — non-x86 builds would break")
        code = line.split("//")[0]  # `unsafe` in a comment is not a use
        if not re.search(r"\bunsafe\b", code) or "// SAFETY:" in line:
            continue
        # Scan upward: a fixed window of plain lines, extended through
        # the contiguous doc-comment/attribute block (where an
        # `unsafe fn`'s `# Safety` section lives).
        documented, plain = False, 0
        for w in reversed(lines[:lineno - 1]):
            ws = w.strip()
            if "// SAFETY:" in w or "# Safety" in ws:
                documented = True
                break
            if not (ws.startswith("//") or ws.startswith("#[")):
                plain += 1
                if plain >= SAFETY_WINDOW:
                    break
        if not documented:
            report(f, lineno,
                   "`unsafe` without a `// SAFETY:` comment (or `# Safety`"
                   " doc section) above it")

# ------------------------------------------------------------- result

if findings:
    print("\n".join(findings))
    print(f"\n{len(findings)} finding(s)")
    sys.exit(1)
print(f"static sweep clean: {len(rust_files)} files, "
      f"{len(mod_tree)} modules, no findings")

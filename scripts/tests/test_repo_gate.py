"""End-to-end gate: knnlint over the real repo must be clean, and the
machine-readable output must obey the published schema."""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]

FINDING_KEYS = {"rule", "severity", "path", "line", "message", "baselined",
                "justification"}


def run_knnlint(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "knnlint"), *args],
        cwd=REPO, capture_output=True, text=True,
    )


def test_repo_is_clean_under_the_committed_baseline(tmp_path):
    out = tmp_path / "lint.json"
    proc = run_knnlint("--json", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr

    data = json.loads(out.read_text())
    assert data["version"] == 1
    assert data["tool"] == "knnlint"
    assert data["files_scanned"] > 0
    assert set(data["counts"]) == {"error", "warning", "info", "baselined", "new"}
    assert data["counts"]["new"] == 0
    assert data["counts"]["baselined"] == len(data["findings"]) >= 0
    for f in data["findings"]:
        assert FINDING_KEYS <= set(f), f
        assert f["baselined"] is True
        assert f["severity"] in ("error", "warning", "info")
        assert isinstance(f["line"], int) and f["line"] >= 1


def test_no_baseline_mode_fails_when_findings_exist():
    # Sanity that the gate has teeth: with the baseline ignored, the
    # grandfathered findings must fail the run (exit 1) — unless the
    # tree is genuinely finding-free, which also proves the gate works.
    proc = run_knnlint("--no-baseline", "-q")
    baseline = json.loads(
        (REPO / "scripts" / "knnlint" / "baseline.json").read_text()
    )
    if baseline["entries"]:
        assert proc.returncode == 1, proc.stdout + proc.stderr
    else:
        assert proc.returncode == 0, proc.stdout + proc.stderr


def test_committed_baseline_entries_are_justified():
    data = json.loads(
        (REPO / "scripts" / "knnlint" / "baseline.json").read_text()
    )
    assert data["version"] == 1
    assert data["entries"], "baseline should carry the grandfathered findings"
    for e in data["entries"]:
        assert e["justification"].strip(), e
        assert e["count"] >= 1


def test_unknown_rule_module_is_an_error():
    proc = run_knnlint("--rules", "nonexistent")
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr

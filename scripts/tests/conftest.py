"""Fixtures for the knnlint test suite.

Tests build throwaway repo trees under tmp_path (a `rust/src/...`
skeleton plus whatever files the scenario needs) and run individual
rule modules against them through the real engine.
"""

import sys
from pathlib import Path

import pytest

SCRIPTS_DIR = Path(__file__).resolve().parents[1]
if str(SCRIPTS_DIR) not in sys.path:
    sys.path.insert(0, str(SCRIPTS_DIR))

FIXTURES = Path(__file__).parent / "fixtures"


@pytest.fixture
def mkrepo(tmp_path):
    """Factory: materialize `{relpath: content}` into a tmp repo root."""

    def make(files):
        for rel, content in files.items():
            p = tmp_path / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            if isinstance(content, bytes):
                p.write_bytes(content)
            else:
                p.write_text(content)
        return tmp_path

    return make


@pytest.fixture
def lint():
    """Run selected rule modules over a root; return the findings."""
    from knnlint.engine import run

    def go(root, only, rule=None):
        ctx = run(root, only=set(only))
        found = ctx.findings
        if rule is not None:
            found = [f for f in found if f.rule == rule]
        return found

    return go


def fixture_text(name):
    return (FIXTURES / name).read_text()

"""Regression tests for the shared Rust lexer/stripper."""

from knnlint.lexer import (
    cfg_test_ranges,
    drop_cfg_test_lines,
    line_of,
    strip_rust,
)


def test_strips_plain_strings_and_comments():
    src = 'let s = "a//b"; // trailing\nlet t = 1; /* block */ let u = 2;\n'
    out = strip_rust(src)
    assert "a//b" not in out
    assert "trailing" not in out
    assert "block" not in out
    assert "let t = 1;" in out
    assert "let u = 2;" in out


def test_strips_cooked_byte_strings():
    # Regression: `b"..."` used to lex as identifier `b` + string, so
    # the content leaked into the stripped text.
    out = strip_rust('const MAGIC: &[u8; 4] = b"KSQ8";\nlet x = 1;\n')
    assert "KSQ8" not in out
    assert '"' not in out
    assert "let x = 1;" in out


def test_strips_raw_byte_strings_any_hash_count():
    # Regression: the old fixed-width prefix window broke on long hash
    # runs and on the `br` prefix itself.
    for hashes in ("", "#", "##", "#####"):
        src = 'let m = br%s"quote \\" and // inside"%s;\nlet y = 2;\n' % (
            hashes,
            hashes,
        )
        out = strip_rust(src)
        assert "inside" not in out, hashes
        assert "let y = 2;" in out, hashes


def test_raw_strings_preserve_newline_count():
    src = 'let m = r#"line1\nline2\nline3"#;\nlet z = 3;\n'
    out = strip_rust(src)
    assert out.count("\n") == src.count("\n")
    assert "line2" not in out
    assert line_of(out, out.index("let z")) == 4


def test_byte_char_literals():
    out = strip_rust("let c = b'x'; let d = b'\\xff'; let e = 5;")
    assert "x" not in out.replace("let e", "")  # b'x' content gone
    assert "let e = 5;" in out


def test_ident_cont_guard_keeps_identifiers_ending_in_b():
    # `ab"..."` is the identifier `ab` followed by a plain string, not
    # a byte-string literal: the identifier must survive.
    assert strip_rust('ab"cd"') == "ab"
    assert strip_rust('b"cd"') == ""


def test_lifetimes_keep_identifier():
    out = strip_rust("fn f<'a>(x: &'a u32) -> &'a u32 { x }")
    assert "f<a>" in out.replace(" ", "")
    assert "'" not in out


def test_nested_block_comments():
    out = strip_rust("a /* x /* y */ z */ b")
    assert out.replace(" ", "") == "ab"


def test_cfg_test_ranges_and_line_blanking():
    src = (
        "pub fn live() {}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        '    fn t() { let s = "secret"; }\n'
        "}\n"
        "pub fn also_live() {}\n"
    )
    stripped = strip_rust(src)
    ranges = cfg_test_ranges(stripped)
    assert len(ranges) == 1
    cleaned = drop_cfg_test_lines(stripped, src)
    assert "secret" not in cleaned
    assert "live()" in cleaned
    assert "also_live" in cleaned
    # Blanking preserves line numbers.
    assert cleaned.count("\n") == src.count("\n")

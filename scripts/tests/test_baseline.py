"""Baseline semantics: count budgets, staleness, justification carry."""

from knnlint import baseline
from knnlint.findings import Finding


def mk(rule="panic-path", path="rust/src/a.rs", line=1, message="m"):
    return Finding(rule=rule, path=path, line=line, message=message,
                   severity="warning")


def test_matching_is_line_number_independent():
    data = baseline.build([mk(line=10)])
    fresh = [mk(line=999)]
    stale = baseline.apply(fresh, data)
    assert fresh[0].baselined
    assert stale == []


def test_count_budget_limits_identical_findings():
    data = baseline.build([mk(), mk()])  # budget of 2 for the same key
    fresh = [mk(line=1), mk(line=2), mk(line=3)]
    baseline.apply(fresh, data)
    assert [f.baselined for f in fresh] == [True, True, False]


def test_stale_entries_are_reported_not_fatal():
    data = baseline.build([mk(message="gone"), mk(message="kept")])
    fresh = [mk(message="kept")]
    stale = baseline.apply(fresh, data)
    assert fresh[0].baselined
    assert len(stale) == 1
    assert stale[0][0][2] == "gone"


def test_build_preserves_hand_edited_justifications():
    first = baseline.build([mk()])
    first["entries"][0]["justification"] = "hand-written rationale"
    second = baseline.build([mk(), mk(message="new one")], previous=first)
    by_msg = {e["message"]: e for e in second["entries"]}
    assert by_msg["m"]["justification"] == "hand-written rationale"
    # New keys get the per-rule default.
    assert by_msg["new one"]["justification"]
    assert by_msg["new one"]["justification"] != "hand-written rationale"


def test_every_built_entry_has_a_justification():
    data = baseline.build(
        [mk(rule=r) for r in ("panic-path", "lock-io", "metrics-coupling", "weird")]
    )
    assert len(data["entries"]) == 4
    for e in data["entries"]:
        assert e["justification"].strip()


def test_unsupported_version_is_rejected(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text('{"version": 99, "entries": []}')
    try:
        baseline.load(p)
    except ValueError as e:
        assert "version" in str(e)
    else:
        raise AssertionError("expected ValueError")

"""Invariant-coupling rule tests: wire magics, metrics names, guards."""

from conftest import fixture_text

KNG2 = 0x4B4E4732


def test_stale_magic_constant_is_detected(mkrepo, lint):
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod graph;\n",
            "rust/src/graph/mod.rs": "pub mod serial;\n",
            "rust/src/graph/serial.rs": fixture_text("stale_magic.rs"),
        }
    )
    found = lint(root, {"coupling"}, rule="magic-coupling")
    assert len(found) == 1
    assert "stale wire-format magic" in found[0].message
    assert "KNG2" in found[0].message


def test_fixture_bytes_must_match_the_constant(mkrepo, lint):
    good = fixture_text("stale_magic.rs").replace("0x4B_4E_47_31", "0x4B_4E_47_32")
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod graph;\n",
            "rust/src/graph/mod.rs": "pub mod serial;\n",
            "rust/src/graph/serial.rs": good,
            # Golden fixture whose first 4 bytes are NOT the magic.
            "rust/tests/data/golden.kng2": b"XXXXrest-of-payload",
        }
    )
    found = lint(root, {"coupling"}, rule="magic-coupling")
    assert len(found) == 1
    assert "regenerate" in found[0].message


def test_matching_constant_and_fixture_are_clean(mkrepo, lint):
    good = fixture_text("stale_magic.rs").replace("0x4B_4E_47_31", "0x4B_4E_47_32")
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod graph;\n",
            "rust/src/graph/mod.rs": "pub mod serial;\n",
            "rust/src/graph/serial.rs": good,
            "rust/tests/data/golden.kng2": KNG2.to_bytes(4, "little") + b"rest",
        }
    )
    assert lint(root, {"coupling"}, rule="magic-coupling") == []


def test_stored_rowref_is_detected(mkrepo, lint):
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod cache;\n",
            "rust/src/cache.rs": fixture_text("stored_rowref.rs"),
        }
    )
    found = lint(root, {"coupling"}, rule="ref-guards")
    assert len(found) == 1
    assert "`Cache` stores a `RowRef`" in found[0].message


def test_static_rowref_return_is_detected(mkrepo, lint):
    src = """
use crate::dataset::store::RowRef;

pub fn leak(store: &Store) -> RowRef<'static> {
    store.row(0)
}
"""
    root = mkrepo({"rust/src/lib.rs": "pub mod m;\n", "rust/src/m.rs": src})
    found = lint(root, {"coupling"}, rule="ref-guards")
    assert len(found) == 1
    assert "'static" in found[0].message or "outlive" in found[0].message


def test_checker_asserting_unrecorded_metric_is_an_error(mkrepo, lint):
    checker = (
        "def main(dump):\n"
        "    assert 'stream.ghost_metric' in dump\n"
    )
    rust = (
        "pub fn record(reg: &Registry) {\n"
        "    reg.counter(\"stream.real_metric\").inc(1);\n"
        "}\n"
    )
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod m;\n",
            "rust/src/m.rs": rust,
            "scripts/check_metrics_snapshot.py": checker,
        }
    )
    found = lint(root, {"coupling"}, rule="metrics-coupling")
    errors = [f for f in found if f.severity == "error"]
    infos = [f for f in found if f.severity == "info"]
    assert len(errors) == 1
    assert "stream.ghost_metric" in errors[0].message
    # The unasserted Rust-side name surfaces as info, not as a failure.
    assert len(infos) == 1
    assert "stream.real_metric" in infos[0].message

"""Lock-order and lock-across-I/O rule tests over seeded fixtures."""

from conftest import fixture_text

LIB = "pub mod fix;\n"


def put(mkrepo, body, extra=None):
    files = {"rust/src/lib.rs": LIB, "rust/src/fix.rs": body}
    files.update(extra or {})
    return mkrepo(files)


def test_declared_order_is_clean(mkrepo, lint):
    root = put(mkrepo, fixture_text("lock_order_ok.rs"))
    assert lint(root, {"locks"}) == []


def test_seeded_inversion_is_detected(mkrepo, lint):
    root = put(mkrepo, fixture_text("lock_order_inversion.rs"))
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "inversion" in found[0].message
    assert "`fix.b` held while acquiring `fix.a`" in found[0].message


def test_declared_cycle_is_detected(mkrepo, lint):
    root = put(mkrepo, fixture_text("lock_order_cycle.rs"))
    found = lint(root, {"locks"}, rule="lock-order")
    assert any("form a cycle" in f.message for f in found)


def test_undeclared_edge_is_detected(mkrepo, lint):
    src = fixture_text("lock_order_ok.rs").replace(
        "// LOCK-ORDER: fix.a -> fix.b\n", ""
    )
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "undeclared lock-order edge" in found[0].message


def test_interprocedural_edge_through_same_file_call(mkrepo, lint):
    src = """
use std::sync::Mutex;

pub struct Pair {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    fn inner(&self) -> u32 {
        let g = self.b.lock().unwrap();
        *g
    }

    pub fn outer(&self) -> u32 {
        let g = self.a.lock().unwrap();
        let v = self.inner();
        *g + v
    }
}
"""
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "`fix.a` held while acquiring `fix.b`" in found[0].message


def test_drop_call_is_not_a_dispatch_to_drop_impl(mkrepo, lint):
    # Regression: `drop(guard)` statements used to resolve as calls to a
    # same-file `Drop::drop` impl, importing its acquisition set.
    src = """
use std::sync::Mutex;

// LOCK-ORDER: fix.a -> fix.b

pub struct Trio {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
    // LOCK-ORDER: fix.c
    c: Mutex<u32>,
}

impl Drop for Trio {
    fn drop(&mut self) {
        let g = self.c.lock().unwrap();
        let _ = *g;
    }
}

impl Trio {
    pub fn ordered(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        let sum = *ga + *gb;
        drop(gb);
        drop(ga);
        sum
    }
}
"""
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert found == [], [f.message for f in found]


def test_reentrant_acquisition_is_detected(mkrepo, lint):
    src = """
use std::sync::Mutex;

pub struct One {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
}

impl One {
    pub fn twice(&self) -> u32 {
        let g1 = self.a.lock().unwrap();
        let g2 = self.a.lock().unwrap();
        *g1 + *g2
    }
}
"""
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "re-entrant" in found[0].message


def test_terminal_lock_must_be_a_leaf(mkrepo, lint):
    src = """
use std::sync::Mutex;

pub struct Pair {
    // LOCK-ORDER: fix.t terminal
    t: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    pub fn bad(&self) -> u32 {
        let gt = self.t.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *gt + *gb
    }

    pub fn fine(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let gt = self.t.lock().unwrap();
        *gt + *gb
    }
}
"""
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "terminal lock `fix.t`" in found[0].message


def test_lock_held_across_io_warns(mkrepo, lint):
    root = put(mkrepo, fixture_text("lock_across_io.rs"))
    found = lint(root, {"locks"}, rule="lock-io")
    assert len(found) == 1
    assert found[0].severity == "warning"
    assert "held across" in found[0].message


def test_allow_io_suppresses_the_io_finding(mkrepo, lint):
    src = fixture_text("lock_across_io.rs").replace(
        "// LOCK-ORDER: fix.w", "// LOCK-ORDER: fix.w allow-io"
    )
    root = put(mkrepo, src)
    assert lint(root, {"locks"}, rule="lock-io") == []


def test_try_lock_is_exempt(mkrepo, lint):
    src = """
use std::sync::Mutex;

pub struct Pair {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    pub fn opportunistic(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        if let Ok(gb) = self.b.try_lock() {
            return *ga + *gb;
        }
        *ga
    }
}
"""
    root = put(mkrepo, src)
    assert lint(root, {"locks"}, rule="lock-order") == []


def test_malformed_annotation_is_a_finding(mkrepo, lint):
    src = """
use std::sync::Mutex;

pub struct One {
    // LOCK-ORDER: fix.a sideways
    a: Mutex<u32>,
}
"""
    root = put(mkrepo, src)
    found = lint(root, {"locks"}, rule="lock-order")
    assert len(found) == 1
    assert "malformed" in found[0].message

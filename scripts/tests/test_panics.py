"""Panic-path audit rule tests."""

from conftest import fixture_text

LIB = "pub mod stream;\n"
STREAM_MOD = "pub mod persist;\n"


def test_forbidden_file_unwrap_is_an_error(mkrepo, lint):
    root = mkrepo(
        {
            "rust/src/lib.rs": LIB,
            "rust/src/stream/mod.rs": STREAM_MOD,
            "rust/src/stream/persist.rs": fixture_text("forbidden_unwrap.rs"),
        }
    )
    found = lint(root, {"panics"}, rule="panic-path")
    assert len(found) == 1
    assert found[0].severity == "error"
    assert found[0].path == "rust/src/stream/persist.rs"
    assert "try_into().unwrap()" in found[0].message


def test_same_unwrap_elsewhere_is_a_warning(mkrepo, lint):
    root = mkrepo(
        {
            "rust/src/lib.rs": "pub mod other;\n",
            "rust/src/other.rs": fixture_text("forbidden_unwrap.rs"),
        }
    )
    found = lint(root, {"panics"}, rule="panic-path")
    assert len(found) == 1
    assert found[0].severity == "warning"


def test_poisoned_lock_idiom_is_allowed(mkrepo, lint):
    src = """
use std::sync::{Mutex, RwLock};

pub fn all_allowed(m: &Mutex<u32>, rw: &RwLock<u32>) -> u32 {
    let a = *m.lock().unwrap();
    let b = *rw.read().unwrap();
    let c = *rw.write().unwrap();
    a + b + c
}
"""
    root = mkrepo({"rust/src/lib.rs": "pub mod m;\n", "rust/src/m.rs": src})
    assert lint(root, {"panics"}, rule="panic-path") == []


def test_panic_ok_comment_suppresses(mkrepo, lint):
    src = """
pub fn f(v: &[u32]) -> u32 {
    // PANIC-OK: the caller guarantees v is non-empty.
    *v.first().unwrap()
}
"""
    root = mkrepo({"rust/src/lib.rs": "pub mod m;\n", "rust/src/m.rs": src})
    assert lint(root, {"panics"}, rule="panic-path") == []


def test_cfg_test_modules_are_exempt(mkrepo, lint):
    src = """
pub fn live() {}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let v: Vec<u32> = vec![1];
        assert_eq!(*v.first().unwrap(), 1);
    }
}
"""
    root = mkrepo({"rust/src/lib.rs": "pub mod m;\n", "rust/src/m.rs": src})
    assert lint(root, {"panics"}, rule="panic-path") == []


def test_unwrap_or_is_not_a_panic_site(mkrepo, lint):
    src = """
pub fn f(v: Option<u32>) -> u32 {
    v.unwrap_or(0) + v.unwrap_or_default()
}
"""
    root = mkrepo({"rust/src/lib.rs": "pub mod m;\n", "rust/src/m.rs": src})
    assert lint(root, {"panics"}, rule="panic-path") == []

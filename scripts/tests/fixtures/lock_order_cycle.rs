// Negative fixture: the declarations themselves form a cycle.
use std::sync::Mutex;

// LOCK-ORDER: fix.a -> fix.b
// LOCK-ORDER: fix.b -> fix.a

pub struct Pair {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    pub fn touch(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        *ga
    }
}

// Negative fixture: GRAPH_MAGIC spells "KNG1", not the expected
// "KNG2" — a stale wire magic. BLOCKED_MAGIC is correct.
pub(crate) const GRAPH_MAGIC: u32 = 0x4B_4E_47_31;
pub(crate) const BLOCKED_MAGIC: u32 = 0x4B_4E_47_33;

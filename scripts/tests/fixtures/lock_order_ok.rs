// Positive fixture: acquisitions follow the declared order exactly.
use std::sync::Mutex;

// LOCK-ORDER: fix.a -> fix.b

pub struct Pair {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    pub fn ordered(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        let sum = *ga + *gb;
        drop(gb);
        drop(ga);
        sum
    }
}

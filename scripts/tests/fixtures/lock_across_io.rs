// Negative fixture: a guard held across file I/O.
use std::sync::Mutex;

pub struct Writer {
    // LOCK-ORDER: fix.w
    w: Mutex<u32>,
}

impl Writer {
    pub fn held_across_io(&self) -> u32 {
        let g = self.w.lock().unwrap();
        let _ = std::fs::read("state.bin");
        *g
    }
}

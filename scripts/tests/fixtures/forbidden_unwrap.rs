// Negative fixture: an unwrap on a persistence error path. Placed at
// rust/src/stream/persist.rs in the test repo, where the panic-path
// rule escalates to error severity.
pub fn parse_header(bytes: &[u8]) -> u32 {
    u32::from_le_bytes(bytes[0..4].try_into().unwrap())
}

pub fn poisoned_lock_is_fine(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

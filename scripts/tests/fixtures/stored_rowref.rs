// Negative fixture: a pin guard stored in a struct field.
use crate::dataset::store::RowRef;

pub struct Cache<'a> {
    row: RowRef<'a>,
    len: usize,
}

// Negative fixture: the declared order is a -> b, the code takes b
// then a — the seeded inversion the suite must detect.
use std::sync::Mutex;

// LOCK-ORDER: fix.a -> fix.b

pub struct Pair {
    // LOCK-ORDER: fix.a
    a: Mutex<u32>,
    // LOCK-ORDER: fix.b
    b: Mutex<u32>,
}

impl Pair {
    pub fn inverted(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        let sum = *ga + *gb;
        drop(ga);
        drop(gb);
        sum
    }
}

#!/usr/bin/env bash
# Tier-1 verify: build, test, and lint the Rust tree.
#
#   bash scripts/verify.sh          # full pass
#   SKIP_CLIPPY=1 bash scripts/verify.sh   # build + test only
#
# `cargo clippy` is skipped automatically when the component is not
# installed (minimal CI containers); the build + test steps are the
# hard gate either way.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

if [ "${SKIP_CLIPPY:-0}" != "1" ] && cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable or skipped — build+test passed"
fi

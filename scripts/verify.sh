#!/usr/bin/env bash
# Tier-1 verify: build (lib/bin + benches), test, format-check, and lint
# the Rust tree.
#
#   bash scripts/verify.sh                 # full pass
#   SKIP_CLIPPY=1 bash scripts/verify.sh   # skip the clippy step
#   SKIP_FMT=1 bash scripts/verify.sh      # skip the rustfmt step
#   FMT_FIX=0 bash scripts/verify.sh       # check-only formatting
#
# `cargo fmt` / `cargo clippy` are skipped automatically when the
# component is not installed (minimal CI containers); the build + test
# steps are the hard gate either way.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
# Benches are plain binaries outside the default build graph; compiling
# them here keeps bench rot a verify failure even when clippy (which
# would also cover --all-targets) is unavailable.
cargo build --benches
cargo test -q

# Formatting is a hard gate (STRICT_FMT defaults to on). FMT_FIX=1 (the
# default) applies `cargo fmt` first, so the one-time initial reflow —
# and any later drift — is absorbed in the same run that checks it;
# set FMT_FIX=0 for check-only CI behaviour.
if [ "${SKIP_FMT:-0}" != "1" ] && cargo fmt --version >/dev/null 2>&1; then
  if [ "${FMT_FIX:-1}" = "1" ]; then
    # Apply first, then gate: the one-time reflow (and any later drift)
    # is absorbed in the same run that checks it — but never silently.
    before=$(git -C . status --porcelain 2>/dev/null || true)
    cargo fmt
    after=$(git -C . status --porcelain 2>/dev/null || true)
    if [ "$before" != "$after" ]; then
      echo "NOTE: cargo fmt rewrote files — review and commit the reflow:"
      git -C . diff --stat 2>/dev/null || true
    fi
  fi
  if ! cargo fmt --check; then
    if [ "${STRICT_FMT:-1}" = "1" ]; then
      echo "cargo fmt --check FAILED"; exit 1
    fi
    echo "WARNING: cargo fmt --check found drift (STRICT_FMT=0)"
  fi
else
  echo "rustfmt unavailable or skipped"
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ] && cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable or skipped — build+test passed"
fi

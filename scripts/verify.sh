#!/usr/bin/env bash
# Tier-1 verify: build, test, format-check, and lint the Rust tree.
#
#   bash scripts/verify.sh          # full pass
#   SKIP_CLIPPY=1 bash scripts/verify.sh   # skip the clippy step
#   SKIP_FMT=1 bash scripts/verify.sh      # skip the rustfmt step
#
# `cargo fmt` / `cargo clippy` are skipped automatically when the
# component is not installed (minimal CI containers); the build + test
# steps are the hard gate either way.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

# Formatting: advisory by default (the tree predates machine
# formatting and the minimal container has no rustfmt to do the initial
# reflow); STRICT_FMT=1 promotes it to a hard gate once `cargo fmt` has
# been run over the tree.
if [ "${SKIP_FMT:-0}" != "1" ] && cargo fmt --version >/dev/null 2>&1; then
  if ! cargo fmt --check; then
    if [ "${STRICT_FMT:-0}" = "1" ]; then
      echo "cargo fmt --check FAILED (strict mode)"; exit 1
    fi
    echo "WARNING: cargo fmt --check found drift (advisory; STRICT_FMT=1 to enforce)"
  fi
else
  echo "rustfmt unavailable or skipped"
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ] && cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable or skipped — build+test passed"
fi

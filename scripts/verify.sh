#!/usr/bin/env bash
# Tier-1 verify: build (lib/bin + benches), test, format-check, and lint
# the Rust tree.
#
#   bash scripts/verify.sh                 # full pass
#   SKIP_CLIPPY=1 bash scripts/verify.sh   # skip the clippy step
#   SKIP_FMT=1 bash scripts/verify.sh      # skip the rustfmt step
#   FMT_FIX=0 bash scripts/verify.sh       # check-only formatting
#   SKIP_CHURN_SMOKE=1 bash scripts/verify.sh   # skip the ~5s bench smoke
#   CHURN_SMOKE_SCALE=0.5 bash scripts/verify.sh # bigger smoke workload
#   SKIP_RESTORE_SMOKE=1 bash scripts/verify.sh # skip the ~5s durability smoke
#   RESTORE_SMOKE_SCALE=0.5 bash scripts/verify.sh # bigger restore workload
#   SKIP_METRICS_SMOKE=1 bash scripts/verify.sh # skip the ~5s metrics smoke
#   SKIP_WAL_SMOKE=1 bash scripts/verify.sh     # skip the ~5s WAL crash smoke
#   SKIP_KERNEL_SMOKE=1 bash scripts/verify.sh  # skip the ~5s kernel smoke
#   KERNEL_SMOKE_SCALE=1 bash scripts/verify.sh # bigger kernel workload
#   SKIP_SERVE_SMOKE=1 bash scripts/verify.sh   # skip the ~5s serve SLO smoke
#   SERVE_SMOKE_SCALE=0.5 bash scripts/verify.sh # bigger serve workload
#
# `cargo fmt` / `cargo clippy` are skipped automatically when the
# component is not installed (minimal CI containers); the build + test
# steps are the hard gate either way.
set -euo pipefail
cd "$(dirname "$0")/.."

# Static analysis first, unconditionally: knnlint needs no toolchain,
# so it gates even containers where every cargo step below is skipped.
# New findings (not in scripts/knnlint/baseline.json) fail the run;
# the machine-readable report lands next to the bench outputs.
mkdir -p results
python3 scripts/knnlint --json results/lint.json -q

cargo build --release
# Benches are plain binaries outside the default build graph; compiling
# them here keeps bench rot a verify failure even when clippy (which
# would also cover --all-targets) is unavailable.
cargo build --benches
cargo test -q

# Churn smoke (~5s at this scale): the stream_churn bench must run end
# to end — inserts, deletes, off-thread seals, reclaim, and the
# batch-rebuild baseline — so the QPS-under-churn numbers can't bit-rot
# between full bench runs. Scale up via CHURN_SMOKE_SCALE.
if [ "${SKIP_CHURN_SMOKE:-0}" != "1" ]; then
  KNN_BENCH_SCALE="${CHURN_SMOKE_SCALE:-0.05}" cargo bench --bench stream_churn
fi

# Durability smoke (~5s at this scale): checkpoint -> kill -> restore
# over a churned log (deletes + upserts), with an eager and a
# budget-paged restore both verified bit-identical against the pre-kill
# index, plus a torn-manifest-write drill. The durability path cannot
# bit-rot between full bench runs. Scale up via RESTORE_SMOKE_SCALE.
if [ "${SKIP_RESTORE_SMOKE:-0}" != "1" ]; then
  KNN_BENCH_SCALE="${RESTORE_SMOKE_SCALE:-0.05}" cargo bench --bench stream_restore
fi

# Metrics smoke (~5s): a short churn run with --metrics-out must emit a
# schema-v1 snapshot carrying the whole observability surface — latency
# histograms with quantiles, seal/compaction/checkpoint span totals,
# budget gauges, and the event journal. Guards the snapshot schema the
# way wire_golden guards the checkpoint format.
smoke_cleanup() { rm -rf ${mdir:+"$mdir"} ${wdir:+"$wdir"}; }
trap smoke_cleanup EXIT

if [ "${SKIP_METRICS_SMOKE:-0}" != "1" ]; then
  mdir=$(mktemp -d)
  target/release/knn-merge stream --family sift --n 3000 --k 8 --lambda 8 \
    --segment-size 500 --report-every 0 --queries 8 --delete-rate 0.2 \
    --checkpoint-dir "$mdir/ckpt" --metrics-out "$mdir/metrics.json" >/dev/null
  python3 scripts/check_metrics_snapshot.py "$mdir/metrics.json"
fi

# WAL crash smoke (~5s): an acknowledged write must survive kill -9.
# First a short run checkpoints cleanly (manifest + truncated WAL).
# Then a throttled run resumes from that checkpoint and is SIGKILLed
# mid-ingest, so the rows it acknowledged live only in the
# group-committed KWAL tail. The final --restore run must come back up
# by replaying that tail and still answer queries — the end-to-end
# durability contract the stream_restore proptests check in-process.
if [ "${SKIP_WAL_SMOKE:-0}" != "1" ]; then
  wdir=$(mktemp -d)
  target/release/knn-merge stream --family sift --n 2000 --k 8 --lambda 8 \
    --segment-size 500 --report-every 0 --queries 0 \
    --checkpoint-dir "$wdir/ckpt" >/dev/null
  target/release/knn-merge stream --family sift --n 20000 --k 8 --lambda 8 \
    --segment-size 500 --rate 2000 --report-every 0 --queries 0 \
    --checkpoint-dir "$wdir/ckpt" --restore >/dev/null 2>&1 &
  wpid=$!
  sleep 2
  kill -9 "$wpid" 2>/dev/null || true
  wait "$wpid" 2>/dev/null || true
  if [ ! -f "$wdir/ckpt/WAL" ]; then
    echo "WAL crash smoke FAILED: no WAL file in the checkpoint dir"; exit 1
  fi
  target/release/knn-merge stream --family sift --n 500 --k 8 --lambda 8 \
    --segment-size 500 --report-every 0 --queries 8 \
    --checkpoint-dir "$wdir/ckpt" --restore >/dev/null
  echo "WAL crash smoke OK: killed mid-ingest, restore replayed the tail"
fi

# Kernel smoke (~5s): the kernels bench must run end to end — scalar vs
# dispatched one-to-many L2 throughput at d32/d128 plus the SQ8 recall
# probe — and the checker gates the >=2x SIMD speedup (when AVX2 was
# detected) and the <=1% quantized recall gap against full precision.
if [ "${SKIP_KERNEL_SMOKE:-0}" != "1" ]; then
  KNN_BENCH_SCALE="${KERNEL_SMOKE_SCALE:-0.5}" cargo bench --bench kernels
  python3 scripts/check_kernels.py results/kernels.json
fi

# Serve smoke (~5s at this scale): the serve_slo bench stands up a live
# KSRV TCP server, drives a mixed search/insert/delete/upsert workload
# from concurrent clients while the compactor runs, then slams the
# admission gate shut for the degradation drill. The checker gates the
# per-class quantile rows and that the drill actually shed ingest and
# degraded searches while every search still answered.
if [ "${SKIP_SERVE_SMOKE:-0}" != "1" ]; then
  KNN_BENCH_SCALE="${SERVE_SMOKE_SCALE:-0.05}" cargo bench --bench serve_slo
  python3 scripts/check_serve_slo.py results/serve_slo.json
fi

# Formatting is a hard gate (STRICT_FMT defaults to on). FMT_FIX=1 (the
# default) applies `cargo fmt` first, so the one-time initial reflow —
# and any later drift — is absorbed in the same run that checks it;
# set FMT_FIX=0 for check-only CI behaviour.
if [ "${SKIP_FMT:-0}" != "1" ] && cargo fmt --version >/dev/null 2>&1; then
  if [ "${FMT_FIX:-1}" = "1" ]; then
    # Apply first, then gate: the reflow is written into the tree so
    # the session can commit it immediately — but drift is still a
    # *failure* (exit 1 below), never an always-pass path.
    before=$(git -C . status --porcelain 2>/dev/null || true)
    cargo fmt
    after=$(git -C . status --porcelain 2>/dev/null || true)
    if [ "$before" != "$after" ]; then
      echo "cargo fmt rewrote files — the reflow is applied, commit it and re-run:"
      git -C . diff --stat 2>/dev/null || true
      if [ "${STRICT_FMT:-1}" = "1" ]; then
        exit 1
      fi
    fi
  fi
  if ! cargo fmt --check; then
    if [ "${STRICT_FMT:-1}" = "1" ]; then
      echo "cargo fmt --check FAILED"; exit 1
    fi
    echo "WARNING: cargo fmt --check found drift (STRICT_FMT=0)"
  fi
else
  echo "rustfmt unavailable or skipped"
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ] && cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy unavailable or skipped — build+test passed"
fi

"""knnlint rule modules. Each exposes `run(ctx)`."""

"""Structural rules 1-5, migrated from the original static_check.py:

  balance      delimiter balance per file (literals/comments stripped)
  modtree      `mod` declarations vs. files on disk (both directions)
  imports      `use crate::…` / `use knn_merge::…` resolution against
               the module tree and each module's `pub` item surface
  cargo-paths  Cargo.toml target paths exist
  fixtures     every committed fixture under rust/tests/data/ is
               referenced by name in at least one rust/tests/*.rs file
"""

import re


def run(ctx):
    _balance(ctx)
    mod_tree = _modtree(ctx)
    _imports(ctx, mod_tree)
    _cargo_paths(ctx)
    _fixtures(ctx)


# ---------------------------------------------------------- 1. balance


def _balance(ctx):
    pairs = {")": "(", "]": "[", "}": "{"}
    for f in ctx.rust_files:
        text = ctx.stripped(f)
        stack = []
        line = 1
        for ch in text:
            if ch == "\n":
                line += 1
            elif ch in "([{":
                stack.append((ch, line))
            elif ch in ")]}":
                if not stack or stack[-1][0] != pairs[ch]:
                    ctx.report("balance", f, line, f"unbalanced '{ch}'")
                    stack = []
                    break
                stack.pop()
        if stack:
            ctx.report("balance", f, stack[-1][1], f"unclosed '{stack[-1][0]}'")


# --------------------------------------------- 2. module tree coverage


def _modtree(ctx):
    lib = ctx.rust_src / "lib.rs"
    if not lib.exists():
        return {}
    mod_tree = {"": lib}

    def walk(dir_path, prefix, decl_file):
        text = ctx.stripped(decl_file)
        for m in re.finditer(r"^\s*(?:pub\s+)?mod\s+(\w+)\s*;", text, re.M):
            name = m.group(1)
            cand = [dir_path / f"{name}.rs", dir_path / name / "mod.rs"]
            hit = next((c for c in cand if c.exists()), None)
            if hit is None:
                ctx.report("modtree", decl_file, text[: m.start()].count("\n") + 1,
                           f"mod {name}: no file {cand[0].name} or {name}/mod.rs")
                continue
            key = f"{prefix}{name}"
            mod_tree[key] = hit
            walk(hit.parent if hit.name == "mod.rs" else dir_path / name,
                 key + "::", hit)

    walk(ctx.rust_src, "", lib)

    declared = set(mod_tree.values())
    for f in ctx.src_files:
        if f.name in ("lib.rs", "main.rs"):
            continue
        if f not in declared:
            ctx.report("modtree", f, 1, "file exists but is not declared by any `mod`")
    return mod_tree


# ----------------------------------- 3. public item surface per module

ITEM_RE = re.compile(
    r"^\s*pub(?:\s*\(.*?\))?\s+"
    r"(?:unsafe\s+)?(?:async\s+)?"
    r"(?:struct|enum|trait|fn|type|const|static|mod|union)\s+"
    r"(\w+)",
    re.M,
)
USE_DECL_RE = re.compile(r"^\s*(?:pub\s+)?use\s+([^;]+);", re.M)


def expand_use(clause):
    """`a::{b, c::d}` -> ['a::b', 'a::c::d'] (handles nesting, `as`)."""
    clause = clause.strip()
    m = re.match(r"^(.*?)\{(.*)\}$", clause, re.S)
    if not m:
        return [re.sub(r"\s+as\s+\w+$", "", clause).strip()]
    head, body = m.group(1), m.group(2)
    parts, depth, cur = [], 0, ""
    for ch in body:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    out = []
    for p in parts:
        out.extend(expand_use(head + p.strip()))
    return out


def _imports(ctx, mod_tree):
    if not mod_tree:
        return
    surface = {}
    for key, path in mod_tree.items():
        text = ctx.stripped(path)
        items = set(ITEM_RE.findall(text))
        items |= set(re.findall(r"macro_rules!\s*(\w+)", text))
        surface[key] = items

    def resolve(path_str):
        segs = [s.strip() for s in path_str.split("::")]
        segs = [s for s in segs if s]
        if not segs:
            return True
        leaf = segs[-1]
        mod_key = "::".join(segs[:-1])
        if mod_key not in mod_tree:
            return False
        if leaf in ("self", "*"):
            return True
        if "::".join(segs) in mod_tree:  # leaf is itself a module
            return True
        if leaf in surface.get(mod_key, set()):
            return True
        # re-exports: `pub use x::y::Leaf;` inside the module
        text = ctx.stripped(mod_tree[mod_key])
        for use in USE_DECL_RE.findall(text):
            for full in expand_use(use):
                if full.split("::")[-1] == leaf or full.endswith("::*"):
                    return True
        return False

    for f in ctx.rust_files:
        text = ctx.stripped(f)
        for m in USE_DECL_RE.finditer(text):
            for full in expand_use(m.group(1)):
                full = full.strip()
                if full.startswith("crate::"):
                    rel = full[len("crate::"):]
                elif full.startswith("knn_merge::"):
                    rel = full[len("knn_merge::"):]
                elif full.startswith(("super::", "self::")):
                    continue  # needs position context; compiler territory
                else:
                    continue  # std / external crates
                if not resolve(rel):
                    ctx.report("imports", f, text[: m.start()].count("\n") + 1,
                               f"unresolved import `{full}`")


# ---------------------------------------------- 4. Cargo target paths


def _cargo_paths(ctx):
    cargo_path = ctx.root / "Cargo.toml"
    if not cargo_path.exists():
        return
    cargo = cargo_path.read_text()
    for m in re.finditer(r'path\s*=\s*"([^"]+)"', cargo):
        if not (ctx.root / m.group(1)).exists():
            ctx.report("cargo-paths", cargo_path,
                       cargo[: m.start()].count("\n") + 1,
                       f"target path {m.group(1)} does not exist")


# ------------------------------------ 5. test fixtures are referenced


def _fixtures(ctx):
    fixture_dir = ctx.root / "rust" / "tests" / "data"
    if not fixture_dir.is_dir():
        return
    # Raw test sources (NOT stripped: fixture names live in string
    # literals, which strip_rust removes).
    test_texts = [ctx.raw(p) for p in sorted((ctx.root / "rust" / "tests").glob("*.rs"))]
    for fx in sorted(fixture_dir.iterdir()):
        if fx.is_file() and not any(fx.name in t for t in test_texts):
            ctx.report("fixtures", fx, 1,
                       "fixture is not referenced by any rust/tests/*.rs test")

"""Rule 7 (migrated): SIMD unsafe is gated and documented.

Intrinsics are the one place this repo allows `unsafe`. Two rules for
any file that touches std::arch / core::arch (checked on RAW text —
the SAFETY comments this rule wants are exactly what strip_rust
drops):

  - every `unsafe` fn/block carries a `// SAFETY:` comment (or, for
    `unsafe fn` declarations, a `/// # Safety` doc section) on the
    same line or in the contiguous comment/attribute block above it,
    so the contract (feature detection, slice bounds) is written down;
  - every `#[target_feature(...)]` fn lives behind a
    `cfg(target_arch = ...)` gate earlier in the file, so the crate
    still compiles (scalar-only) on other architectures.
"""

import re

SAFETY_WINDOW = 4


def run(ctx):
    for f in ctx.rust_files:
        raw = ctx.raw(f)
        if "std::arch" not in raw and "core::arch" not in raw:
            continue
        lines = raw.split("\n")
        has_arch_gate = False
        for lineno, line in enumerate(lines, 1):
            if re.search(r"cfg\s*\(\s*target_arch", line):
                has_arch_gate = True
            if re.search(r"#\[target_feature", line) and not has_arch_gate:
                ctx.report("simd", f, lineno,
                           "#[target_feature] with no cfg(target_arch=...) gate "
                           "earlier in the file — non-x86 builds would break")
            code = line.split("//")[0]  # `unsafe` in a comment is not a use
            if not re.search(r"\bunsafe\b", code) or "// SAFETY:" in line:
                continue
            # Scan upward: a fixed window of plain lines, extended
            # through the contiguous doc-comment/attribute block (where
            # an `unsafe fn`'s `# Safety` section lives).
            documented, plain = False, 0
            for w in reversed(lines[: lineno - 1]):
                ws = w.strip()
                if "// SAFETY:" in w or "# Safety" in ws:
                    documented = True
                    break
                if not (ws.startswith("//") or ws.startswith("#[")):
                    plain += 1
                    if plain >= SAFETY_WINDOW:
                        break
            if not documented:
                ctx.report("simd", f, lineno,
                           "`unsafe` without a `// SAFETY:` comment (or `# Safety`"
                           " doc section) above it")

"""Panic-path audit: classify `unwrap()` / `expect()` sites.

Allowed (no finding):
  - the poisoned-mutex idiom: `.lock().unwrap()`, `.read().unwrap()`,
    `.write().unwrap()`, `.into_inner().unwrap()`, condvar
    `.wait(..).unwrap()` / `.wait_timeout(..).unwrap()` — a poisoned
    lock means another thread already panicked; propagating is the
    only sane policy in this codebase;
  - anything inside `#[cfg(test)]` modules, rust/tests/, rust/benches/,
    examples/ — panics are the test failure mechanism;
  - lines (or the line above) carrying a `// PANIC-OK: <reason>`
    comment — the written-down contract for a deliberate panic.

Everything else is a finding: severity `error` in the durability /
dataset-I/O error paths (`stream/persist.rs`, `dataset/io.rs`) where a
panic loses data that a `Result` would have surfaced, `warning`
elsewhere. Pre-existing sites are grandfathered by the baseline; new
ones fail the gate.
"""

import re

from ..lexer import cfg_test_ranges, line_of

PANIC_RE = re.compile(r"\.\s*(unwrap|expect)\s*\(")
# The receiver chain directly before `.unwrap()` that marks the
# poisoned-lock idiom. `[^()]*` keeps `.wait(guard)` / `.expect("…")`
# arguments from defeating the match.
ALLOWED_TAIL = re.compile(
    r"\.\s*(?:lock|read|write|try_lock|into_inner|wait|wait_timeout)"
    r"\s*\([^()]*\)\s*$"
)
FORBIDDEN_FILES = {"rust/src/stream/persist.rs", "rust/src/dataset/io.rs"}


def run(ctx):
    for f in ctx.src_files:
        text = ctx.stripped(f)
        raw_lines = ctx.raw(f).split("\n")
        skip = cfg_test_ranges(text)
        rel = ctx.rel(f)
        severity = "error" if rel in FORBIDDEN_FILES else "warning"
        for m in PANIC_RE.finditer(text):
            if any(s <= m.start() < e for s, e in skip):
                continue
            before = text[: m.start()]
            if ALLOWED_TAIL.search(before[-120:]):
                continue
            lineno = line_of(text, m.start())
            nearby = raw_lines[max(0, lineno - 2) : lineno]
            if any("PANIC-OK" in ln for ln in nearby):
                continue
            snippet = raw_lines[lineno - 1].strip()
            if len(snippet) > 90:
                snippet = snippet[:87] + "..."
            ctx.report("panic-path", f, lineno,
                       f"`{m.group(1)}()` outside the allowed idioms: `{snippet}`",
                       severity=severity)

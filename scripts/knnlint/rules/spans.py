"""Rule 6 (migrated): Span guards are RAII, never manual.

A `Span::enter` whose guard is not bound to a variable is dropped at
the end of the statement — it times nothing. `let _ =` is the same bug
spelled differently (`_` drops immediately; `_span` does not), and a
manual `Span::exit` API must never grow back: unwinds would skip it
and corrupt the nesting stack.
"""

import re

SPAN_ENTER_RE = re.compile(r"Span\s*::\s*enter(?:_billed)?\b")
SPAN_BARE_RE = re.compile(r"^\s*(?:crate::metrics::|metrics::)?Span\s*::\s*enter")
SPAN_WILD_RE = re.compile(r"let\s+_\s*=")


def run(ctx):
    for f in ctx.rust_files:
        text = ctx.stripped(f)
        for lineno, line in enumerate(text.split("\n"), 1):
            if re.search(r"Span\s*::\s*exit\b", line):
                ctx.report("span-raii", f, lineno,
                           "Span::exit: spans are RAII-only, use the guard")
            if not SPAN_ENTER_RE.search(line):
                continue
            if SPAN_BARE_RE.match(line):
                ctx.report("span-raii", f, lineno,
                           "Span::enter guard dropped immediately — bind it: "
                           "`let _span = Span::enter(...)`")
            elif SPAN_WILD_RE.search(line.split("Span")[0]):
                ctx.report("span-raii", f, lineno,
                           "`let _ = Span::enter(...)` drops the guard at once — "
                           "name it `_span`")

"""Lock-order checking against declared `// LOCK-ORDER:` annotations.

Two findings families:

  lock-order  an acquired-while-held edge between two annotated locks
              that the declared partial order does not allow (an
              inversion or an undeclared edge), a re-entrant
              acquisition, a terminal lock held across another
              acquisition, or an inconsistency in the declarations
              themselves (cycles, unbound annotations).
  lock-io     a lock held across file I/O, a channel `recv()`, or a
              kernel-dispatch call, unless the lock is annotated
              `allow-io`.

Annotation grammar (written in normal `//` comments):

  binding form — on or directly above a lock field/static/local:

      // LOCK-ORDER: <dotted.name> [terminal] [allow-io]
      segments: Mutex<Arc<SegmentSet>>,

    binds the field identifier to the dotted lock name. `terminal`
    locks may be acquired while holding anything but must not be held
    while acquiring another annotated lock. `allow-io` suppresses the
    held-across-I/O findings for this lock.

  edge form — anywhere (typically module docs):

      // LOCK-ORDER: a.name -> b.name -> c.name

    declares consecutive pairs as allowed acquisition order. The
    checker verifies observed edges against the transitive closure.

Analysis model (no compiler, stripped text):

  - `.lock()` / `.read()` / `.write()` with empty parens are
    acquisitions; `try_lock` is deliberately exempt (non-blocking
    acquisition cannot deadlock in an ordering sense).
  - `let g = x.lock().unwrap();` holds until `drop(g)` or the end of
    the enclosing block; any other acquisition form is a statement
    temporary (held to the next `;`).
  - Per-file interprocedural closure: calls through `self.method(...)`,
    `Self::f(...)` and bare `f(...)` to functions defined in the same
    file propagate the callee's acquisition set to the caller's held
    scopes. Cross-file calls are out of scope (metrics locks are
    terminal, which covers the common cross-module pattern).
  - Lock identifiers resolve per-file first, then through the global
    annotation map when unambiguous; unannotated locks are ignored by
    the ordering check but still subject to lock-io.
"""

import re
from collections import defaultdict

from ..lexer import brace_blocks, innermost_block, line_of

ANN_RE = re.compile(r"//\s*LOCK-ORDER:\s*(.+?)\s*$", re.M)
FIELD_RE = re.compile(
    r"(?:pub(?:\s*\([^)]*\))?\s+)?(\w+)\s*:\s*[^=;{]*?\b(?:Mutex|RwLock)\s*<"
)
STATIC_RE = re.compile(r"\bstatic\s+(\w+)\s*:")
LET_RE = re.compile(r"\blet\s+(?:mut\s+)?(\w+)\b")
FLAGS = {"terminal", "allow-io"}

ACQ_RE = re.compile(
    r"([A-Za-z_][A-Za-z0-9_]*(?:\s*\.\s*[A-Za-z_][A-Za-z0-9_]*|\s*\[[^\[\]]*\])*)"
    r"\s*\.\s*(lock|read|write)\s*\(\s*\)"
)
FN_RE = re.compile(r"\bfn\s+(\w+)")
CALL_RE = re.compile(r"(self\s*\.\s*|Self\s*::\s*)?\b([A-Za-z_]\w*)\s*\(")
IO_RE = re.compile(
    r"File\s*::|OpenOptions|\bfs\s*::\s*\w|\.sync_all\s*\(|\.sync_data\s*\(|"
    r"\.recv\s*\(|\.recv_timeout\s*\(|\.execute\s*::\s*<|\.seek\s*\(|"
    r"\.read_exact\s*\(|\.read_to_end\s*\(|\.write_all\s*\("
)
# What a guard binding may chain through and still be "just the guard".
GUARD_TAIL_RE = re.compile(r"^(\s*\.\s*(unwrap|expect)\s*\([^()]*\))?\s*;")


def _lock_ident(expr):
    expr = re.sub(r"\[[^\[\]]*\]", "", expr)
    return expr.split(".")[-1].strip()


class FileLocks:
    """Per-file annotation + acquisition scan."""

    def __init__(self, ctx, path):
        self.ctx = ctx
        self.path = path
        self.raw = ctx.raw(path)
        self.text = ctx.stripped(path)
        self.bindings = {}  # ident -> lock name (this file's declarations)
        self.edges = []  # (a, b, line) declared here
        self.flags = defaultdict(set)  # lock name -> flags
        self._parse_annotations()
        self.blocks = brace_blocks(self.text)
        self.acqs = self._acquisitions()
        self.fns = self._functions()
        self.calls = self._call_sites()

    # ---------------------------------------------------- annotations

    def _parse_annotations(self):
        raw_lines = self.raw.split("\n")
        for m in ANN_RE.finditer(self.raw):
            body = m.group(1).strip()
            lineno = line_of(self.raw, m.start())
            if "->" in body:
                names = [p.strip() for p in body.split("->")]
                if any(not re.fullmatch(r"[\w.]+", n) for n in names):
                    self.ctx.report("lock-order", self.path, lineno,
                                    f"malformed LOCK-ORDER edge annotation: {body!r}")
                    continue
                for a, b in zip(names, names[1:]):
                    self.edges.append((a, b, lineno))
                continue
            tokens = body.split()
            name, flags = tokens[0], set(tokens[1:])
            if not re.fullmatch(r"[\w.]+", name) or not flags <= FLAGS:
                self.ctx.report("lock-order", self.path, lineno,
                                f"malformed LOCK-ORDER annotation: {body!r} "
                                f"(want `name [terminal] [allow-io]`)")
                continue
            ident = self._bind_target(raw_lines, lineno)
            if ident is None:
                self.ctx.report("lock-order", self.path, lineno,
                                f"LOCK-ORDER annotation {name!r} does not bind to a "
                                f"lock declaration on this or the next lines")
                continue
            self.bindings[ident] = name
            self.flags[name] |= flags

    def _bind_target(self, raw_lines, lineno):
        # Same line (code before the comment), then up to 4 lines below.
        same = raw_lines[lineno - 1].split("//")[0]
        for probe in [same] + raw_lines[lineno : lineno + 4]:
            code = probe.split("//")[0]
            for pat in (FIELD_RE, STATIC_RE, LET_RE):
                m = pat.search(code)
                if m:
                    return m.group(1)
            if code.strip().startswith("#["):  # attributes pass through
                continue
            if code.strip():  # a non-lock code line breaks the binding
                return None
        return None

    # --------------------------------------------------- acquisitions

    def _acquisitions(self):
        """[(offset, end, ident, guard_var|None, hold_end)] sorted."""
        acqs = []
        for m in ACQ_RE.finditer(self.text):
            p, end = m.start(), m.end()
            ident = _lock_ident(m.group(1))
            indexed = "[" in m.group(1)
            guard_var, hold_end = None, None
            stmt_start = max(self.text.rfind(sep, 0, p) for sep in ";{}") + 1
            seg = self.text[stmt_start:p]
            letm = re.search(r"\blet\s+(?:mut\s+)?(\w+)\s*(?::[^=]*)?=\s*\S*$", seg)
            iflet = re.search(r"\bif\s+let\s+Ok\(\s*(?:mut\s+)?(\w+)\s*\)\s*=\s*\S*$", seg)
            if letm and GUARD_TAIL_RE.match(self.text[end:]):
                guard_var = letm.group(1)
                hold_end = self._hold_end(p, guard_var)
            elif iflet:
                guard_var = iflet.group(1)
                hold_end = self._hold_end(p, guard_var)
            else:
                semi = self.text.find(";", end)
                hold_end = len(self.text) if semi < 0 else semi
            acqs.append({
                "off": p, "end": end, "ident": ident, "indexed": indexed,
                "guard": guard_var, "hold_end": hold_end,
                "line": line_of(self.text, p),
            })
        return acqs

    def _hold_end(self, p, var):
        block = innermost_block(self.blocks, p)
        scope_end = block[1] if block else len(self.text)
        dropm = re.compile(r"\bdrop\s*\(\s*%s\s*\)" % re.escape(var)).search(
            self.text, p, scope_end
        )
        return dropm.start() if dropm else scope_end

    # ------------------------------------------------------ functions

    def _functions(self):
        """name -> list of (body_start, body_end)."""
        fns = defaultdict(list)
        for m in FN_RE.finditer(self.text):
            i, depth = m.end(), 0
            n = len(self.text)
            while i < n:
                c = self.text[i]
                if c == "(":
                    depth += 1
                elif c == ")":
                    depth -= 1
                elif c == "{" and depth == 0:
                    break
                elif c == ";" and depth == 0:  # trait method, no body
                    i = -1
                    break
                i += 1
            if i < 0 or i >= n:
                continue
            block = next((b for b in self.blocks if b[0] == i), None)
            if block:
                fns[m.group(1)].append(block)
        return fns

    def _call_sites(self):
        """[(offset, callee_name)] for same-file callables."""
        calls = []
        for m in CALL_RE.finditer(self.text):
            name = m.group(2)
            if name not in self.fns:
                continue
            # `drop(x)` is std's prelude fn; a same-file `Drop::drop`
            # impl is never what a bare `drop(...)` call dispatches to.
            if name == "drop":
                continue
            recv = m.group(1)
            before = self.text[: m.start(2)].rstrip()
            if recv is None:
                # Bare call: reject method calls on other receivers,
                # `::`-qualified paths, and the definition site itself.
                if before.endswith(".") or before.endswith("::"):
                    continue
                if re.search(r"\bfn\s*$", before):
                    continue
            calls.append((m.start(), name))
        return calls

    def containing_fn(self, offset):
        best = None
        for name, spans in self.fns.items():
            for s, e in spans:
                if s < offset <= e and (best is None or s > best[1]):
                    best = (name, s, e)
        return best[0] if best else None


def _resolve(ident, local, global_map):
    if ident in local:
        return local[ident]
    names = global_map.get(ident, set())
    return next(iter(names)) if len(names) == 1 else None


def _transitive(edges):
    adj = defaultdict(set)
    for a, b in edges:
        adj[a].add(b)
    closure = set()
    for start in list(adj):
        seen, stack = set(), [start]
        while stack:
            node = stack.pop()
            for nxt in adj.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        closure |= {(start, t) for t in seen}
    return closure


def _declared_cycles(edges):
    adj = defaultdict(set)
    for a, b in edges:
        adj[a].add(b)
    color, order = {}, []
    cycle = []

    def dfs(u, path):
        color[u] = 1
        for v in adj.get(u, ()):
            if color.get(v) == 1:
                cycle.append(path + [v])
                return
            if v not in color:
                dfs(v, path + [v])
        color[u] = 2
        order.append(u)

    for u in list(adj):
        if u not in color:
            dfs(u, [u])
    return cycle


def run(ctx):
    files = [FileLocks(ctx, p) for p in ctx.src_files]

    # Global annotation state.
    global_map = defaultdict(set)  # ident -> {lock names}
    flags = defaultdict(set)
    declared = set()
    edge_decl_site = {}
    for fl in files:
        for ident, name in fl.bindings.items():
            global_map[ident].add(name)
        for name, fset in fl.flags.items():
            flags[name] |= fset
        for a, b, lineno in fl.edges:
            declared.add((a, b))
            edge_decl_site.setdefault((a, b), (fl.path, lineno))

    for path_cycle in _declared_cycles(declared):
        first = tuple(path_cycle[-2:])
        path, lineno = edge_decl_site.get(first, (files[0].path if files else "?", 1))
        ctx.report("lock-order", path, lineno,
                   "declared LOCK-ORDER edges form a cycle: "
                   + " -> ".join(path_cycle))

    allowed = _transitive(declared)

    for fl in files:
        acq_sets, io_flags = _interprocedural(fl)
        _check_file(ctx, fl, global_map, flags, allowed, acq_sets, io_flags)


def _interprocedural(fl):
    """Fixpoint: per-function acquired-lock idents and direct-I/O flag."""
    direct_acq = defaultdict(set)
    direct_io = defaultdict(bool)
    fn_calls = defaultdict(set)
    for a in fl.acqs:
        fn = fl.containing_fn(a["off"])
        if fn:
            direct_acq[fn].add(a["ident"])
    for name, spans in fl.fns.items():
        for s, e in spans:
            if IO_RE.search(fl.text, s, e):
                direct_io[name] = True
    for off, callee in fl.calls:
        fn = fl.containing_fn(off)
        if fn and fn != callee:
            fn_calls[fn].add(callee)

    acq = {f: set(s) for f, s in direct_acq.items()}
    io = dict(direct_io)
    changed = True
    while changed:
        changed = False
        for f, callees in fn_calls.items():
            for c in callees:
                add = acq.get(c, set()) - acq.setdefault(f, set())
                if add:
                    acq[f] |= add
                    changed = True
                if io.get(c) and not io.get(f):
                    io[f] = True
                    changed = True
    return acq, io


def _check_file(ctx, fl, global_map, flags, allowed, acq_sets, io_flags):
    edges_seen = set()

    def note_edge(held, ident_b, line, indexed_pair):
        a = held["name"]
        b = _resolve(ident_b, fl.bindings, global_map)
        if a is None or b is None:
            return
        if a == b:
            if not indexed_pair:
                ctx.report("lock-order", fl.path, line,
                           f"re-entrant acquisition: lock `{a}` acquired while "
                           f"already held — self-deadlock")
            return
        if (a, b) in edges_seen:
            return
        edges_seen.add((a, b))
        if "terminal" in flags.get(a, ()):
            ctx.report("lock-order", fl.path, line,
                       f"terminal lock `{a}` held while acquiring `{b}` — "
                       f"terminal locks must be leaves of every hold chain")
            return
        if "terminal" in flags.get(b, ()):
            return
        if (a, b) in allowed:
            return
        if (b, a) in allowed:
            ctx.report("lock-order", fl.path, line,
                       f"lock-order inversion: `{a}` held while acquiring `{b}`, "
                       f"but the declared order is `{b}` -> `{a}`")
        else:
            ctx.report("lock-order", fl.path, line,
                       f"undeclared lock-order edge: `{a}` held while acquiring "
                       f"`{b}` — declare `// LOCK-ORDER: {a} -> {b}` or fix")

    held_intervals = []
    for a in fl.acqs:
        if a["guard"] is not None:
            held_intervals.append({
                "name": _resolve(a["ident"], fl.bindings, global_map),
                "ident": a["ident"], "indexed": a["indexed"],
                "start": a["end"], "end": a["hold_end"], "line": a["line"],
            })

    # Order edges: held guard -> later acquisition / callee closure.
    for h in held_intervals:
        if h["name"] is None:
            continue
        for a in fl.acqs:
            if h["start"] < a["off"] < h["end"]:
                note_edge(h, a["ident"], a["line"],
                          h["indexed"] and a["indexed"] and h["ident"] == a["ident"])
        for off, callee in fl.calls:
            if h["start"] < off < h["end"]:
                for ident_b in sorted(acq_sets.get(callee, ())):
                    note_edge(h, ident_b, line_of(fl.text, off), False)

    # lock-io: holds across I/O / recv / kernel dispatch.
    io_reported = set()

    def note_io(name, ident, line, why):
        if name and "allow-io" in flags.get(name, ()):
            return
        key = (ident, line)
        if key in io_reported:
            return
        io_reported.add(key)
        label = name or ident
        ctx.report("lock-io", fl.path, line,
                   f"lock `{label}` held across {why} — annotate the lock "
                   f"`allow-io` with a rationale, or move the call out of the "
                   f"critical section", severity="warning")

    for h in held_intervals:
        m = IO_RE.search(fl.text, h["start"], h["end"])
        if m:
            note_io(h["name"], h["ident"], line_of(fl.text, m.start()),
                    f"`{m.group(0).strip()}`")
        else:
            for off, callee in fl.calls:
                if h["start"] < off < h["end"] and io_flags.get(callee):
                    note_io(h["name"], h["ident"], line_of(fl.text, off),
                            f"call to I/O-performing `{callee}()`")
                    break
    for a in fl.acqs:
        if a["guard"] is None:
            m = IO_RE.search(fl.text, a["end"], a["hold_end"])
            if m:
                name = _resolve(a["ident"], fl.bindings, global_map)
                note_io(name, a["ident"], a["line"], f"`{m.group(0).strip()}`")

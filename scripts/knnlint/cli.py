"""knnlint command line.

    python3 scripts/knnlint                    # text findings, exit 1 on new
    python3 scripts/knnlint --json results/lint.json
    python3 scripts/knnlint --update-baseline  # re-seed the baseline
    python3 scripts/knnlint --rules locks,panics

Exit code 0 = every finding is covered by the committed baseline
(scripts/knnlint/baseline.json). Any non-baselined finding exits 1,
regardless of severity — severities shape triage, not the gate.
"""

import argparse
import json
import sys
from pathlib import Path

from . import baseline as baseline_mod
from .engine import MODULE_RULES, all_rules, run

PACKAGE_DIR = Path(__file__).resolve().parent
DEFAULT_ROOT = PACKAGE_DIR.parent.parent
DEFAULT_BASELINE = PACKAGE_DIR / "baseline.json"


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="knnlint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--root", type=Path, default=DEFAULT_ROOT,
                    help="repo root to scan (default: the repo containing this package)")
    ap.add_argument("--json", type=Path, metavar="PATH", dest="json_out",
                    help="also write machine-readable findings to PATH")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline file (default: scripts/knnlint/baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: every finding fails")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(preserves existing justifications) and exit 0")
    ap.add_argument("--rules", metavar="LIST",
                    help="comma-separated rule modules to run "
                         f"(default: all of {','.join(n for n, _ in all_rules())})")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress baselined findings in the text output")
    args = ap.parse_args(argv)

    only = set(args.rules.split(",")) if args.rules else None
    if only:
        known = {n for n, _ in all_rules()}
        unknown = only - known
        if unknown:
            ap.error(f"unknown rule module(s): {', '.join(sorted(unknown))}")

    ctx = run(args.root, only=only)
    findings = ctx.findings

    if args.update_baseline:
        previous = baseline_mod.load(args.baseline) if args.baseline.exists() else None
        data = baseline_mod.build(findings, previous)
        baseline_mod.save(args.baseline, data)
        print(f"baseline updated: {len(data['entries'])} entr(y/ies) covering "
              f"{len(findings)} finding(s) -> {args.baseline}")
        return 0

    stale = []
    if not args.no_baseline:
        try:
            data = baseline_mod.load(args.baseline)
        except ValueError as e:
            print(f"knnlint: {e}", file=sys.stderr)
            return 2
        stale = baseline_mod.apply(findings, data)
        if only:
            # A subset run can't judge entries owned by modules that
            # didn't execute — only report staleness for rules that ran.
            ran = set().union(*(MODULE_RULES[m] for m in only))
            stale = [(k, n) for k, n in stale if k[0] in ran]

    new = [f for f in findings if not f.baselined]
    old = [f for f in findings if f.baselined]

    if args.json_out:
        args.json_out.parent.mkdir(parents=True, exist_ok=True)
        counts = {"error": 0, "warning": 0, "info": 0}
        for f in findings:
            counts[f.severity] += 1
        payload = {
            "version": 1,
            "tool": "knnlint",
            "root": str(ctx.root),
            "files_scanned": len(ctx.rust_files),
            "rules": sorted({f.rule for f in findings} | (only or set())),
            "counts": {**counts, "baselined": len(old), "new": len(new)},
            "findings": [f.to_json() for f in findings],
            "stale_baseline_entries": [
                {"rule": k[0], "path": k[1], "message": k[2], "count": n}
                for k, n in stale
            ],
        }
        args.json_out.write_text(json.dumps(payload, indent=2) + "\n")

    shown = new if args.quiet else findings
    for f in shown:
        print(f.text())
    if stale:
        print(f"note: {sum(n for _, n in stale)} stale baseline entr(y/ies) no "
              f"longer match — prune with --update-baseline")
    if new:
        print(f"\n{len(new)} new finding(s) ({len(old)} baselined)")
        return 1
    print(f"knnlint clean: {len(ctx.rust_files)} files, "
          f"{len(old)} baselined finding(s), 0 new")
    return 0

"""Shared Rust lexer/stripper for all knnlint rules.

`strip_rust` removes comments and string/char literals while preserving
newlines, so downstream rules can regex over *code* without tripping on
text. It understands:

  - line comments `//...` and nested block comments `/* /* */ */`,
  - cooked strings `"..."` (with escapes),
  - raw strings `r"..."`, `r#"..."#`, ... (any number of hashes),
  - byte strings `b"..."` and raw byte strings `br"..."`, `br#"..."#`
    (previously lexed as identifier + plain string — the `b`/`r`
    prefix leaked into the stripped text and long hash runs broke the
    raw-string detection window),
  - char and byte-char literals `'x'`, `'\n'`, `b'x'`, `b'\xff'`,
  - lifetimes `'a` (the tick is dropped, the identifier is kept).

Multi-line literals keep their newline count so line numbers computed
on the stripped text match the raw file.
"""

import re

_RAW_PREFIX = re.compile(r'b?r(#*)"')


def strip_rust(text: str) -> str:
    """Remove string/char literals and comments, preserving newlines."""
    out = []
    i, n = 0, len(text)
    prev = ""  # last raw character consumed (guards prefix detection)
    while i < n:
        c = text[i]
        two = text[i : i + 2]
        # `b"`/`r"`/`br#"` are literal prefixes only when they start a
        # token — `crc32b` followed by something is an identifier.
        ident_cont = prev.isalnum() or prev == "_"
        if two == "//":
            j = text.find("\n", i)
            i = n if j < 0 else j
            prev = ""
        elif two == "/*":
            depth, i = 1, i + 2
            while i < n and depth:
                if text[i : i + 2] == "/*":
                    depth, i = depth + 1, i + 2
                elif text[i : i + 2] == "*/":
                    depth, i = depth - 1, i + 2
                else:
                    if text[i] == "\n":
                        out.append("\n")
                    i += 1
            prev = ""
        elif not ident_cont and _RAW_PREFIX.match(text, i):
            m = _RAW_PREFIX.match(text, i)
            hashes = m.group(1)
            end = text.find('"' + hashes, m.end())
            seg = text[i : end + 1 + len(hashes)] if end >= 0 else text[i:]
            out.append("\n" * seg.count("\n"))
            i = n if end < 0 else end + 1 + len(hashes)
            prev = '"'
        elif c == '"' or (not ident_cont and two == 'b"'):
            j = i + (2 if c == "b" else 1)
            while j < n and text[j] != '"':
                j += 2 if text[j] == "\\" else 1
            out.append("\n" * text[i:j].count("\n"))
            i = j + 1
            prev = '"'
        elif c == "'" or (not ident_cont and two == "b'"):
            t = i if c == "'" else i + 1  # index of the opening tick
            if t + 1 < n and text[t + 1] == "\\":
                j = text.find("'", t + 2)
                i = t + 2 if j < 0 else j + 1
                prev = "'"
            elif t + 2 < n and text[t + 2] == "'":
                i = t + 3
                prev = "'"
            elif c != "'":  # malformed `b'…`; consume the prefix only
                out.append(c)
                i += 1
                prev = c
            else:  # lifetime — keep the tick out, keep the ident
                i += 1
                prev = "'"
        else:
            out.append(c)
            i += 1
            prev = c
    return "".join(out)


def line_of(text: str, offset: int) -> int:
    """1-based line number of `offset` in `text`."""
    return text.count("\n", 0, offset) + 1


def brace_blocks(text: str):
    """All `{...}` intervals as (open_offset, close_offset) pairs.

    `text` must be stripped (no braces inside literals/comments).
    Unclosed blocks extend to the end of the text.
    """
    stack, blocks = [], []
    for i, ch in enumerate(text):
        if ch == "{":
            stack.append(i)
        elif ch == "}" and stack:
            blocks.append((stack.pop(), i))
    for open_ in stack:
        blocks.append((open_, len(text)))
    return blocks


CFG_TEST_RE = re.compile(r"#\[cfg\(test\)\]\s*(?:pub\s+)?mod\s+\w+\s*\{")


def cfg_test_ranges(text):
    """Offset ranges of `#[cfg(test)] mod … { … }` blocks in stripped text."""
    blocks = brace_blocks(text)
    ranges = []
    for m in CFG_TEST_RE.finditer(text):
        open_off = text.find("{", m.start())
        block = next((b for b in blocks if b[0] == open_off), None)
        if block:
            ranges.append(block)
    return ranges


def drop_cfg_test_lines(stripped: str, raw: str) -> str:
    """`raw` with the lines of `#[cfg(test)]` modules blanked out.

    Stripped and raw text agree on line numbers (strip_rust preserves
    newlines), so test blocks found in the stripped form map straight
    onto raw lines.
    """
    spans = [
        (line_of(stripped, s), line_of(stripped, e))
        for s, e in cfg_test_ranges(stripped)
    ]
    if not spans:
        return raw
    out = []
    for idx, ln in enumerate(raw.split("\n"), 1):
        out.append("" if any(a <= idx <= b for a, b in spans) else ln)
    return "\n".join(out)


def innermost_block(blocks, offset):
    """The tightest (open, close) interval containing `offset`."""
    best = None
    for open_, close in blocks:
        if open_ < offset < close:
            if best is None or open_ > best[0]:
                best = (open_, close)
    return best

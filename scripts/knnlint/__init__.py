"""knnlint — static analysis gate for the knn-merge repo.

A modular rule engine over the Rust tree that runs without a Rust
toolchain: structural tripwires (delimiter balance, module tree,
import resolution, Cargo targets, fixture references), observability
hygiene (RAII spans, SIMD safety comments), concurrency invariants
(declared `// LOCK-ORDER:` partial order, locks held across I/O), a
panic-path audit, and cross-layer coupling checks (wire-format magics
vs. fixtures vs. gen_fixtures.py, metric names vs. the metrics smoke,
RowRef/ListRef pin-guard discipline).

Run `python3 scripts/knnlint --help`. Findings not covered by the
committed baseline (scripts/knnlint/baseline.json) fail the gate.
"""

from .engine import Context, run  # noqa: F401
from .findings import Finding  # noqa: F401
from .lexer import strip_rust  # noqa: F401

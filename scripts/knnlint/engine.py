"""Rule engine: file discovery, caching, and rule dispatch.

Rules are functions `run(ctx)` that call `ctx.report(...)`. The
Context owns the raw/stripped text caches so each file is read and
lexed once no matter how many rules look at it.
"""

from pathlib import Path

from .findings import Finding, SEVERITIES
from .lexer import strip_rust


class Context:
    def __init__(self, root):
        self.root = Path(root).resolve()
        self.rust_src = self.root / "rust" / "src"
        self.findings: list[Finding] = []
        self._raw: dict[Path, str] = {}
        self._stripped: dict[Path, str] = {}

    # ------------------------------------------------------- discovery

    @property
    def src_files(self) -> list[Path]:
        return sorted(self.rust_src.rglob("*.rs")) if self.rust_src.is_dir() else []

    @property
    def rust_files(self) -> list[Path]:
        """Everything the sweep covers: src, tests, benches, examples."""
        return (
            self.src_files
            + sorted((self.root / "rust").glob("tests/*.rs"))
            + sorted((self.root / "rust").glob("benches/*.rs"))
            + sorted(self.root.glob("examples/*.rs"))
        )

    # --------------------------------------------------------- caching

    def raw(self, path: Path) -> str:
        if path not in self._raw:
            self._raw[path] = path.read_text()
        return self._raw[path]

    def stripped(self, path: Path) -> str:
        if path not in self._stripped:
            self._stripped[path] = strip_rust(self.raw(path))
        return self._stripped[path]

    # ------------------------------------------------------- reporting

    def rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root).as_posix()
        except ValueError:
            return path.as_posix()

    def report(self, rule, path, line, message, severity="error"):
        assert severity in SEVERITIES, severity
        self.findings.append(
            Finding(rule=rule, path=self.rel(Path(path)), line=line,
                    message=message, severity=severity)
        )


# Finding-rule ids each module can emit — used to scope baseline
# staleness checks to the modules that actually ran.
MODULE_RULES = {
    "structure": {"balance", "modtree", "imports", "cargo-paths", "fixtures"},
    "spans": {"span-raii"},
    "simd": {"simd"},
    "locks": {"lock-order", "lock-io"},
    "panics": {"panic-path"},
    "coupling": {"magic-coupling", "metrics-coupling", "ref-guards"},
}


def all_rules():
    """Ordered (name, run) pairs. Import here to avoid cycles."""
    from .rules import coupling, locks, panics, simd, spans, structure

    return [
        ("structure", structure.run),
        ("spans", spans.run),
        ("simd", simd.run),
        ("locks", locks.run),
        ("panics", panics.run),
        ("coupling", coupling.run),
    ]


def run(root, only=None) -> Context:
    """Run rule modules over `root`; returns the populated Context."""
    ctx = Context(root)
    for name, fn in all_rules():
        if only and name not in only:
            continue
        fn(ctx)
    ctx.findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return ctx

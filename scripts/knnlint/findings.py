"""Finding model: what a rule reports and how the baseline matches it.

A finding's identity is `(rule, path, message)` — deliberately *not*
the line number, so committed baselines survive unrelated edits that
shift code up or down. Identical findings in one file (e.g. several
grandfathered `unwrap()`s with the same snippet) are matched by count.
"""

from dataclasses import dataclass, field

SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    message: str
    severity: str = "error"
    baselined: bool = False
    justification: str = ""

    def key(self):
        return (self.rule, self.path, self.message)

    def text(self) -> str:
        tag = " (baselined)" if self.baselined else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "baselined": self.baselined,
            "justification": self.justification or None,
        }

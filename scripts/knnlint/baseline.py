"""Committed-baseline handling: new findings fail, grandfathered pass.

The baseline file is JSON:

    {"version": 1,
     "entries": [{"rule": ..., "path": ..., "message": ...,
                  "count": 1, "justification": "..."}]}

Matching is by `(rule, path, message)` with a per-key count budget —
line numbers are ignored so the baseline survives unrelated edits.
Every entry must carry a one-line justification; `--update-baseline`
seeds one from per-rule defaults and preserves hand-edited text on
refresh.
"""

import json
from collections import Counter

BASELINE_VERSION = 1

# Seed justifications for `--update-baseline`. Hand-edit the baseline
# afterwards where a site deserves a more specific rationale.
DEFAULT_JUSTIFICATIONS = {
    "panic-path": (
        "grandfathered at the PR-8 panic-audit seed; new sites need a "
        "`// PANIC-OK:` rationale or a Result-returning fix"
    ),
    "lock-io": (
        "reviewed: the hold is intentional (see the adjacent code "
        "comment) and the lock is not an annotatable named field"
    ),
    "lock-order": "reviewed at baseline seed; scheduled for untangling",
    "metrics-coupling": (
        "recorded in Rust but not asserted by the metrics smoke — the "
        "smoke checks a representative subset of the surface"
    ),
}
FALLBACK_JUSTIFICATION = "grandfathered pre-existing finding (PR-8 baseline seed)"


def load(path):
    if not path.exists():
        return {"version": BASELINE_VERSION, "entries": []}
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version {data.get('version')!r}")
    return data


def apply(findings, data):
    """Mark findings covered by the baseline; return stale entries.

    Mutates `findings` in place (sets `.baselined` / `.justification`).
    Returns a list of `(key, unused_count)` for baseline entries that no
    longer match anything — candidates for pruning.
    """
    budget = Counter()
    just = {}
    for e in data.get("entries", []):
        k = (e["rule"], e["path"], e["message"])
        budget[k] += int(e.get("count", 1))
        just[k] = e.get("justification", "")
    for f in findings:
        k = f.key()
        if budget[k] > 0:
            budget[k] -= 1
            f.baselined = True
            f.justification = just.get(k, "")
    return [(k, n) for k, n in sorted(budget.items()) if n > 0]


def build(findings, previous=None):
    """Construct baseline data from the current findings.

    Justifications carry over from `previous` where the key matches;
    new keys get the per-rule default.
    """
    prev_just = {}
    for e in (previous or {}).get("entries", []):
        prev_just[(e["rule"], e["path"], e["message"])] = e.get("justification", "")
    counts = Counter(f.key() for f in findings)
    entries = []
    for (rule, path, message), count in sorted(counts.items()):
        justification = prev_just.get((rule, path, message)) or DEFAULT_JUSTIFICATIONS.get(
            rule, FALLBACK_JUSTIFICATION
        )
        entries.append(
            {
                "rule": rule,
                "path": path,
                "message": message,
                "count": count,
                "justification": justification,
            }
        )
    return {"version": BASELINE_VERSION, "entries": entries}


def save(path, data):
    path.write_text(json.dumps(data, indent=2, sort_keys=False) + "\n")

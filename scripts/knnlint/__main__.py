"""Entry point for both `python3 scripts/knnlint` (directory execution,
where sys.path[0] is the package dir itself) and `python3 -m knnlint`."""

import os
import sys

if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    __package__ = "knnlint"  # noqa: A001

from knnlint.cli import main

sys.exit(main())

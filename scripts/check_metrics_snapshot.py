#!/usr/bin/env python3
"""Validate a MetricsSnapshot JSON dump (schema v1) from a stream run.

Used by scripts/verify.sh as the metrics smoke: after a short churn run
with --metrics-out, the snapshot must carry the full observability
surface — latency histograms with quantiles, per-phase span totals
covering seal/compaction/checkpoint, budget gauges, registry counters,
and a non-empty event journal.

Usage: check_metrics_snapshot.py <metrics.json>
"""

import json
import sys

ERRORS = []


def err(msg):
    ERRORS.append(msg)


def require(obj, key, kind=None, where="snapshot"):
    if key not in obj:
        err(f"{where}: missing key {key!r}")
        return None
    v = obj[key]
    if kind is not None and not isinstance(v, kind):
        err(f"{where}.{key}: expected {kind}, got {type(v).__name__}")
        return None
    return v


HIST_KEYS = ["count", "max_ns", "mean_ns", "p50_ns", "p95_ns", "p99_ns", "p999_ns"]


def check_histogram(hists, name):
    h = require(hists, name, dict, "histograms")
    if h is None:
        return
    for key in HIST_KEYS:
        require(h, key, (int, float), f"histograms.{name}")
    if h.get("count", 0) <= 0:
        err(f"histograms.{name}: count must be > 0, got {h.get('count')}")
    p50, p99 = h.get("p50_ns", 0), h.get("p99_ns", 0)
    if p50 > p99:
        err(f"histograms.{name}: p50 {p50} > p99 {p99}")
    if h.get("max_ns", 0) < p99:
        err(f"histograms.{name}: max_ns below p99")


def check_span(spans, name):
    s = require(spans, name, dict, "spans")
    if s is None:
        return
    require(s, "phase", str, f"spans.{name}")
    if s.get("count", 0) <= 0:
        err(f"spans.{name}: count must be > 0")
    if s.get("self_ns", -1) < 0:
        err(f"spans.{name}: self_ns missing or negative")


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            snap = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL {path}: unreadable or invalid JSON: {e}", file=sys.stderr)
        return 1

    if snap.get("version") != 1:
        err(f"version must be 1, got {snap.get('version')!r}")
    require(snap, "uptime_s", (int, float))

    counters = require(snap, "counters", dict) or {}
    if counters.get("stream.inserted", 0) <= 0:
        err("counters.stream.inserted must be > 0")
    for key in ["stream.deleted", "stream.sealed", "stream.compactions"]:
        if key not in counters:
            err(f"counters: missing {key!r}")

    gauges = require(snap, "gauges", dict) or {}
    for key in ["budget.faults", "budget.evictions", "budget.resident_bytes"]:
        if key not in gauges:
            err(f"gauges: missing {key!r}")

    hists = require(snap, "histograms", dict) or {}
    for name in ["stream.insert_ns", "stream.search_ns"]:
        check_histogram(hists, name)

    # Service-layer surface: the stream driver routes every insert,
    # delete, and measured search through the Service, so the per-class
    # histograms must carry samples. The admission counters and
    # in-flight gauges are registered at service construction; a smoke
    # run that never sheds legitimately leaves them at 0, so presence
    # (not value) is the contract.
    for name in ["service.insert_ns", "service.search_ns"]:
        check_histogram(hists, name)
    for name in ["service.delete_ns", "service.upsert_ns", "service.control_ns"]:
        require(hists, name, dict, "histograms")
    for key in ["service.rejected_insert", "service.rejected_delete",
                "service.rejected_upsert", "service.degraded_searches"]:
        if key not in counters:
            err(f"counters: missing {key!r}")
    for key in ["service.inflight_search", "service.inflight_ingest"]:
        if key not in gauges:
            err(f"gauges: missing {key!r}")
    # Degradation magnitude histogram: registered at service
    # construction; a smoke run that never degrades leaves count 0, so
    # presence (not value) is the contract.
    require(hists, "service.search_degradation", dict, "histograms")

    # WAL surface: the smoke runs with --checkpoint-dir, so the engine
    # attaches the group-committed KWAL — every insert/delete lands in
    # the commit histogram and record counter.
    check_histogram(hists, "stream.wal_commit_ns")
    if counters.get("stream.wal_records", 0) <= 0:
        err("counters.stream.wal_records must be > 0")

    spans = require(snap, "spans", dict) or {}
    for name in ["seal_build", "compaction", "checkpoint"]:
        check_span(spans, name)

    events = require(snap, "events", list) or []
    if not events:
        err("events: journal is empty")
    kinds = {e.get("kind") for e in events if isinstance(e, dict)}
    # wal_replay fires even on a fresh WAL (records=0); wal_truncate at
    # the final checkpoint; incremental_spill at every seal publish.
    for kind in ["seal_published", "compaction", "checkpoint",
                 "wal_replay", "wal_truncate", "incremental_spill"]:
        if kind not in kinds:
            err(f"events: no {kind!r} event (got kinds {sorted(k for k in kinds if k)})")

    if ERRORS:
        print(f"FAIL {path}: {len(ERRORS)} problem(s)", file=sys.stderr)
        for e in ERRORS:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"OK {path}: metrics snapshot v1 complete "
          f"({len(hists)} histograms, {len(spans)} spans, {len(events)} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

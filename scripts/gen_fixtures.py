#!/usr/bin/env python3
"""Regenerate the golden wire-format fixtures under rust/tests/data/.

The fixtures pin the on-disk byte layout of:

  - the flat graph format        (KNG2, graph::serial::graph_to_bytes)
  - the row-blocked spill format (KNG3, graph::serial::write_graph_blocked)
  - the search-graph spill       (KIDX, stream::persist::index_to_bytes)
  - the checkpoint manifest      (KNM1, stream::persist::manifest_to_bytes)
  - the write-ahead row log      (KWAL, stream::wal::encode_record)

plus deliberately damaged variants (truncation, flipped CRC byte) that
readers must reject with a clean error. `rust/tests/wire_golden.rs`
asserts byte-identical round-trips against these files, so any format
edit breaks loudly there — rerun this script ONLY when a format change
is intentional, and bump the relevant version/magic when you do.

This script is the independent second implementation of each format:
it shares no code with the Rust writers, so agreement is evidence the
spec comments in serial.rs / persist.rs match reality.
"""

import struct
import zlib
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "tests" / "data"
OUT.mkdir(parents=True, exist_ok=True)

u8 = lambda v: struct.pack("<B", v)
u16 = lambda v: struct.pack("<H", v)
u32 = lambda v: struct.pack("<I", v)
u64 = lambda v: struct.pack("<Q", v)
f32 = lambda v: struct.pack("<f", v)

# The one shared graph: k=4, span offset 7, 3 rows.
#   row0: (8, 0.25, new) (9, 0.5, old)   row1: empty   row2: (7, 1.5, new)
ROWS = [[(8, 0.25, 1), (9, 0.5, 0)], [], [(7, 1.5, 1)]]
K, SPAN_OFFSET = 4, 7


def encode_row(row):
    out = u16(len(row))
    for nid, dist, new in row:
        out += u32(nid) + f32(dist) + u8(new)
    return out


# ------------------------------------------------------------- KNG2
kng2 = u32(0x4B4E4732) + u32(K) + u32(SPAN_OFFSET) + u64(len(ROWS))
for row in ROWS:
    kng2 += encode_row(row)
(OUT / "golden.kng2").write_bytes(kng2)

# ------------------------------------------------------------- KNG3
BLOCK_ROWS = 2
nblocks = (len(ROWS) + BLOCK_ROWS - 1) // BLOCK_ROWS
blocks = [
    b"".join(encode_row(r) for r in ROWS[i : i + BLOCK_ROWS])
    for i in range(0, len(ROWS), BLOCK_ROWS)
]
header = (
    u32(0x4B4E4733)
    + u32(K)
    + u32(SPAN_OFFSET)
    + u64(len(ROWS))
    + u32(BLOCK_ROWS)
    + u32(nblocks)
)
offsets, pos = [], len(header) + (nblocks + 1) * 8
for b in blocks:
    offsets.append(pos)
    pos += len(b)
offsets.append(pos)
kng3 = header + b"".join(u64(o) for o in offsets) + b"".join(blocks)
(OUT / "golden.kng3").write_bytes(kng3)
(OUT / "golden_truncated.kng3").write_bytes(kng3[:-1])

# ------------------------------------------------------------- KIDX
kidx = (
    u32(0x4B494458)
    + u32(3)  # max_degree
    + u32(1)  # entry
    + u64(3)  # n
    + u32(2)  # n_entries
    + u32(1)
    + u32(0)
    # adjacency rows: [1], [0, 2], []
    + u16(1)
    + u32(1)
    + u16(2)
    + u32(0)
    + u32(2)
    + u16(0)
)
(OUT / "golden.kidx").write_bytes(kidx)

# ---------------------------------------------------------- manifest
payload = (
    u32(2)  # dim
    + u8(0)  # metric: L2
    + u64(0x0123456789ABCDEF)  # config fingerprint
    + u64(0xB10C1D0000000001)  # log id
    + u32(9)  # next_gid
    + u64(4)  # next_segment_id
    + u64(9)  # inserted
    + u64(2)  # deleted
    + u64(2)  # sealed
    + u64(1)  # compactions
    + u64(1)  # reclaimed
    + u64(1)  # upserted
    + u64(5)  # tombstone_epoch
    + u32(2) + u32(3) + u32(6)            # tombstones [3, 6]
    + u32(1) + u32(8) + u32(2)            # bindings [(8 -> gid 2)]
    + u32(1) + u32(2) + u32(8)            # current [(gid 2 -> 8)]
    + u32(2)                               # two segments
    + u64(0) + u32(0) + u32(3) + u32(0) + u32(1) + u32(4)
    + u64(3) + u32(1) + u32(2) + u32(5) + u32(7)
    + u32(1) + u32(8) + f32(1.5) + f32(-2.0)  # memtable [(8, [1.5, -2.0])]
)
manifest = (
    u32(0x4B4E4D31)  # "KNM1"
    + u32(1)  # version
    + u64(len(payload))
    + payload
    + u32(zlib.crc32(payload) & 0xFFFFFFFF)
)
(OUT / "golden.manifest").write_bytes(manifest)
(OUT / "golden_truncated.manifest").write_bytes(manifest[: len(manifest) // 2])
bad = bytearray(manifest)
bad[16 + len(payload) // 2] ^= 0x20  # flip one payload bit -> CRC must catch it
(OUT / "golden_badcrc.manifest").write_bytes(bytes(bad))

# -------------------------------------------------------------- KWAL
# Group-committed write-ahead row log: 24-byte header (magic, version,
# reserved, log id, logical base position), then length+CRC-framed
# records. Unlike the manifest, damage is NOT an error here: a torn or
# garbled record frame is a clean end-of-log (the crash hit mid group
# commit, so nothing at or past it was ever acknowledged).
wal_header = (
    u32(0x4B57414C)  # "KWAL"
    + u16(1)  # version
    + u16(0)  # reserved
    + u64(0xB10C1D0000000001)  # log id (same world as golden.manifest)
    + u64(0)  # base_pos: nothing truncated yet
)


def wal_frame(payload):
    return u32(len(payload)) + u32(zlib.crc32(payload) & 0xFFFFFFFF) + payload


wal_payloads = [
    u8(0) + u32(9) + u32(2) + f32(1.5) + f32(-2.0),  # insert gid 9, dim 2
    u8(1) + u32(3),  # delete gid 3
    u8(2) + u32(2) + u32(10) + u32(2) + f32(0.25) + f32(4.0),  # upsert gid 2 -> internal 10
]
kwal = wal_header + b"".join(wal_frame(p) for p in wal_payloads)
(OUT / "golden.kwal").write_bytes(kwal)
# Torn tail: the last frame lost its final 3 bytes mid-write.
(OUT / "golden_truncated.kwal").write_bytes(kwal[:-3])
# Flipped payload bit in the last record: the CRC drops exactly it.
badw = bytearray(kwal)
badw[-1] ^= 0x20
(OUT / "golden_badcrc.kwal").write_bytes(bytes(badw))

for f in sorted(OUT.iterdir()):
    print(f"{f.relative_to(OUT.parent.parent.parent)}  {f.stat().st_size} bytes")

//! Out-of-core construction demo (paper Sec. IV): build a k-NN graph
//! with only two of `p` subsets resident in memory at any time, the
//! rest parked in external storage. Storage time is modelled at the
//! paper's SSD throughput (7450/6900 MB/s) from the real spilled bytes.
//!
//! ```bash
//! cargo run --release --example out_of_core_build
//! ```

use knn_merge::config::RunConfig;
use knn_merge::construction::NnDescentParams;
use knn_merge::coordinator::build_out_of_core;
use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::Metric;
use knn_merge::eval::recall::{graph_recall, GroundTruth};
use knn_merge::merge::MergeParams;
use knn_merge::metrics::Phase;

fn main() {
    let n = 12_000;
    let ds = DatasetFamily::Sift.generate(n, 3);
    let truth = GroundTruth::sampled(&ds, 10, Metric::L2, 200, 5);
    println!("sift-like n={n}: out-of-core build (2/p subsets resident)\n");
    println!(
        "{:>6} {:>9} {:>9} {:>12} {:>11} {:>10}",
        "parts", "build_s", "merge_s", "storage_s*", "spilled_MB", "recall@10"
    );
    for parts in [2usize, 4, 6] {
        let cfg = RunConfig {
            parts,
            merge: MergeParams {
                k: 20,
                lambda: 12,
                ..Default::default()
            },
            nnd: NnDescentParams {
                k: 20,
                lambda: 12,
                ..Default::default()
            },
            ..Default::default()
        };
        let (graph, ledger) = build_out_of_core(&ds, &cfg).expect("out-of-core build");
        let recall = graph_recall(&graph, &truth, 10);
        println!(
            "{:>6} {:>9.2} {:>9.2} {:>12.4} {:>11.1} {:>10.4}",
            parts,
            ledger.secs(Phase::Build),
            ledger.secs(Phase::Merge),
            ledger.secs(Phase::Storage),
            ledger.bytes_stored() as f64 / 1e6,
            recall
        );
    }
    // The same build under a hard residency budget (2/p of the data for
    // p = 4): the paged spills evict cold chunks mid-round, so the
    // ceiling holds even though every merge scans both subsets fully.
    let budget = ds.payload_bytes() / 2;
    let cfg = RunConfig {
        parts: 4,
        memory_budget: budget,
        merge: MergeParams {
            k: 20,
            lambda: 12,
            ..Default::default()
        },
        nnd: NnDescentParams {
            k: 20,
            lambda: 12,
            ..Default::default()
        },
        ..Default::default()
    };
    let (graph, ledger) = build_out_of_core(&ds, &cfg).expect("budgeted build");
    println!(
        "\nbudgeted (p=4, budget {:.1} MB): peak resident {:.1} MB, \
         {} faults, {} evictions, recall@10 {:.4}",
        budget as f64 / 1e6,
        ledger.peak_resident_bytes() as f64 / 1e6,
        ledger.chunk_faults(),
        ledger.chunk_evictions(),
        graph_recall(&graph, &truth, 10)
    );

    println!("\n(*) modelled at the paper's SSD sequential throughput; the real");
    println!("bytes are written and read back through the spill files, billed");
    println!("per paged-in chunk. more parts -> more pairwise merges (C(p,2))");
    println!("but a flat memory ceiling — the trade Sec. IV describes for");
    println!("memory-bound nodes.");
}

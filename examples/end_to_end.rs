//! End-to-end driver: exercises the **full three-layer system** on a
//! real (synthetic but calibrated) workload and reports the paper's
//! headline metric — construction time vs quality against NN-Descent
//! from scratch.
//!
//! Layers exercised:
//!   L1/L2 — the AOT Pallas distance kernel, loaded from
//!           `artifacts/*.hlo.txt` and executed via PJRT from the Rust
//!           hot path (batched Local-Join),
//!   L3   — the distributed peer-to-peer coordinator (Alg. 3) on a
//!           simulated 3-node cluster with the 1 Gbps network model.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use knn_merge::config::RunConfig;
use knn_merge::construction::{NnDescent, NnDescentParams};
use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::{Metric, ScalarEngine};
use knn_merge::distributed::run_cluster;
use knn_merge::eval::bench::{BenchReport, Row};
use knn_merge::eval::recall::{graph_recall, GroundTruth};
use knn_merge::merge::{MergeParams, TwoWayMerge};
use knn_merge::runtime::XlaEngine;

fn main() {
    let n: usize = std::env::var("E2E_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let k = 20;
    let lambda = 12;
    let mut report = BenchReport::new("end_to_end");
    report.note(format!(
        "workload: sift-like n={n} d=128, k={k} lambda={lambda}, 1-core container"
    ));

    let ds = DatasetFamily::Sift.generate(n, 42);
    let truth = GroundTruth::sampled(&ds, 10, Metric::L2, 300, 7);
    let merge_params = MergeParams {
        k,
        lambda,
        ..Default::default()
    };
    let nnd_params = NnDescentParams {
        k,
        lambda,
        ..Default::default()
    };

    // --- Baseline: NN-Descent from scratch on one node -------------
    let t = std::time::Instant::now();
    let baseline = NnDescent::new(nnd_params).build(&ds, Metric::L2);
    let baseline_secs = t.elapsed().as_secs_f64();
    let baseline_recall = graph_recall(&baseline, &truth, 10);
    report.push(
        Row::new("nn-descent (scratch)")
            .col("time_s", baseline_secs)
            .col("recall@10", baseline_recall),
    );

    // --- L1+L2: AOT Pallas kernel on the PJRT runtime ---------------
    // One Two-way Merge run with the Local-Join hot path dispatching
    // batched distance tiles to the compiled artifact. This proves the
    // Python-authored kernel is the one executing inside the Rust
    // coordinator (python itself is NOT running here).
    let artifact_dir = XlaEngine::default_artifact_dir();
    let parts = ds.split_contiguous(2);
    let g1 = NnDescent::new(nnd_params).build(&parts[0].0, Metric::L2);
    let g2 = NnDescent::new(nnd_params).build(&parts[1].0, Metric::L2);
    match XlaEngine::load_for_dim(&artifact_dir, ds.dim) {
        Ok(engine) => {
            let t = std::time::Instant::now();
            let merged = TwoWayMerge::new(merge_params).merge_observed(
                &parts[0].0,
                &parts[1].0,
                &g1,
                &g2,
                Metric::L2,
                &engine,
                &mut |_, _, _| {},
            );
            let secs = t.elapsed().as_secs_f64();
            let r = graph_recall(&merged, &truth, 10);
            report.push(
                Row::new("two-way merge (xla/pallas engine)")
                    .col("time_s", secs)
                    .col("recall@10", r)
                    .col("pjrt_dispatches", engine.dispatch_count() as f64),
            );
            assert!(r > 0.9, "XLA-engine merge recall too low: {r}");
        }
        Err(e) => {
            eprintln!("skipping XLA engine stage ({e}); run `make artifacts`");
        }
    }

    // Same merge on the scalar engine (the production default on CPU).
    let t = std::time::Instant::now();
    let merged = TwoWayMerge::new(merge_params).merge_observed(
        &parts[0].0,
        &parts[1].0,
        &g1,
        &g2,
        Metric::L2,
        &ScalarEngine,
        &mut |_, _, _| {},
    );
    let scalar_secs = t.elapsed().as_secs_f64();
    let scalar_recall = graph_recall(&merged, &truth, 10);
    report.push(
        Row::new("two-way merge (scalar engine)")
            .col("time_s", scalar_secs)
            .col("recall@10", scalar_recall),
    );

    // --- L3: distributed construction on a simulated 3-node cluster --
    let cfg = RunConfig {
        parts: 3,
        merge: merge_params,
        nnd: nnd_params,
        ..Default::default()
    };
    let result = run_cluster(&ds, &cfg);
    let r = graph_recall(&result.graph, &truth, 10);
    report.push(
        Row::new("multi-node (3 nodes, Alg.3)")
            .col("time_s", result.modelled_makespan())
            .col("recall@10", r)
            .col("exchanged_MB", result.bytes_exchanged() as f64 / 1e6),
    );
    assert!(r > 0.9, "distributed recall too low: {r}");

    report.note(format!(
        "headline: 3-node construction at {:.2}x the speed of scratch NN-Descent \
         with equal-or-better quality (paper Tab. III reports ~2.4x on 3 nodes)",
        baseline_secs / result.modelled_makespan().max(1e-9)
    ));
    report.finish();
    println!("end_to_end OK");
}

//! Distributed construction demo: the same dataset built on 3, 5 and 7
//! simulated nodes (Alg. 3), showing the node-scaling behaviour of
//! paper Fig. 13 and the cost breakdown of Fig. 14.
//!
//! ```bash
//! cargo run --release --example distributed_build
//! ```

use knn_merge::config::RunConfig;
use knn_merge::construction::NnDescentParams;
use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::Metric;
use knn_merge::distributed::run_cluster;
use knn_merge::eval::recall::{graph_recall, GroundTruth};
use knn_merge::merge::MergeParams;

fn main() {
    let n = 12_000;
    let ds = DatasetFamily::Deep.generate(n, 7);
    let truth = GroundTruth::sampled(&ds, 10, Metric::L2, 200, 3);
    println!("deep-like n={n}: distributed construction (1 Gbps model)\n");
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>9}  breakdown",
        "nodes", "makespan", "recall@10", "exchangedMB", "wall"
    );
    for nodes in [3usize, 5, 7] {
        let cfg = RunConfig {
            parts: nodes,
            merge: MergeParams {
                k: 20,
                lambda: 12,
                ..Default::default()
            },
            nnd: NnDescentParams {
                k: 20,
                lambda: 12,
                ..Default::default()
            },
            ..Default::default()
        };
        let result = run_cluster(&ds, &cfg);
        let recall = graph_recall(&result.graph, &truth, 10);
        let breakdown: Vec<String> = result
            .breakdown()
            .into_iter()
            .filter(|(_, pct)| *pct > 0.05)
            .map(|(p, pct)| format!("{}={pct:.1}%", p.name()))
            .collect();
        println!(
            "{:>6} {:>9.2}s {:>10.4} {:>12.2} {:>8.2}s  {}",
            nodes,
            result.modelled_makespan(),
            recall,
            result.bytes_exchanged() as f64 / 1e6,
            result.wall_secs,
            breakdown.join(" ")
        );
    }
    println!("\nnote: on this 1-core container the per-node compute shares one");
    println!("core, so wall-clock does not drop with node count — the modelled");
    println!("makespan (max over nodes of compute+exchange) is the deployment");
    println!("figure, matching the shape of paper Fig. 13.");
}

//! Indexing-graph merge demo (paper Sec. V-D): build HNSW and Vamana
//! indexes on two subsets, merge them with Two-way Merge + the source
//! method's own Eq. (1) diversification (Sec. III-B, no-eviction
//! union), and compare NN-search QPS/recall against the same index
//! built from scratch on the full set.
//!
//! ```bash
//! cargo run --release --example index_merge_search
//! ```

use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::Metric;
use knn_merge::eval::recall::{search_recall, GroundTruth};
use knn_merge::index::search::run_queries;
use knn_merge::index::{Hnsw, HnswParams, Vamana, VamanaParams};
use knn_merge::merge::index_merge::{merge_two_index_graphs, IndexKind};
use knn_merge::merge::MergeParams;

fn main() {
    let n = 6_000;
    let ds = DatasetFamily::Deep.generate(n, 11);
    let queries = DatasetFamily::Deep.generate_queries(100, 11);
    let truth = GroundTruth::for_queries(&ds, &queries, 10, Metric::L2);
    let parts = ds.split_contiguous(2);

    println!("== HNSW (M=16, efC=128) ==");
    {
        let hp = HnswParams::default();
        let t = std::time::Instant::now();
        let full = Hnsw::build(&ds, Metric::L2, hp);
        let scratch_secs = t.elapsed().as_secs_f64();

        // Subset indexes exist already in the motivating scenario; their
        // build time is not part of the merge cost.
        let h1 = Hnsw::build(&parts[0].0, Metric::L2, hp);
        let h2 = Hnsw::build(&parts[1].0, Metric::L2, hp);

        let t = std::time::Instant::now();
        let merged = merge_two_index_graphs(
            &parts[0].0,
            &parts[1].0,
            &h1.to_knn_graph(&parts[0].0, Metric::L2),
            &h2.to_knn_graph(&parts[1].0, Metric::L2),
            Metric::L2,
            MergeParams {
                k: 2 * hp.m,
                lambda: 16,
                ..Default::default()
            },
            IndexKind::Hnsw,
            2 * hp.m,
        );
        let merge_secs = t.elapsed().as_secs_f64();

        let full_ig = full.base_index();
        for (label, ig, secs) in [
            ("scratch", &full_ig, scratch_secs),
            ("merged ", &merged, merge_secs),
        ] {
            let (results, qps, _) = run_queries(&ds, Metric::L2, ig, &queries, 10, 64);
            let r = search_recall(&results, &truth, 10);
            println!("  {label}: build {secs:6.2}s   QPS {qps:8.0}   recall@10 {r:.4}");
        }
    }

    println!("== Vamana (R=32, L=64, alpha=1.2) ==");
    {
        let vp = VamanaParams::default();
        let t = std::time::Instant::now();
        let full = Vamana::build(&ds, Metric::L2, vp);
        let scratch_secs = t.elapsed().as_secs_f64();

        let v1 = Vamana::build(&parts[0].0, Metric::L2, vp);
        let v2 = Vamana::build(&parts[1].0, Metric::L2, vp);

        let t = std::time::Instant::now();
        let merged = merge_two_index_graphs(
            &parts[0].0,
            &parts[1].0,
            &v1.to_knn_graph(&parts[0].0, Metric::L2),
            &v2.to_knn_graph(&parts[1].0, Metric::L2),
            Metric::L2,
            MergeParams {
                k: vp.r,
                lambda: 16,
                ..Default::default()
            },
            IndexKind::Vamana { alpha: vp.alpha },
            vp.r,
        );
        let merge_secs = t.elapsed().as_secs_f64();

        for (label, ig, secs) in [
            ("scratch", &full.graph, scratch_secs),
            ("merged ", &merged, merge_secs),
        ] {
            let (results, qps, _) = run_queries(&ds, Metric::L2, ig, &queries, 10, 64);
            let r = search_recall(&results, &truth, 10);
            println!("  {label}: build {secs:6.2}s   QPS {qps:8.0}   recall@10 {r:.4}");
        }
    }
    println!("\nexpectation (paper Figs. 10-12): merged indexes search within ~5%");
    println!("of scratch-built ones while the merge costs a fraction of a rebuild.");
}

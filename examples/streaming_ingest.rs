//! Streaming ingest: insert 10k vectors into the online segment-log
//! index while answering queries, with compaction running on a
//! background thread — then check that the fully-compacted streamed
//! graph matches a batch NN-Descent build on the same data.
//!
//! ```bash
//! cargo run --release --example streaming_ingest
//! ```

use knn_merge::config::StreamConfig;
use knn_merge::construction::{NnDescent, NnDescentParams};
use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::Metric;
use knn_merge::eval::recall::{graph_recall, GroundTruth};
use knn_merge::merge::MergeParams;
use knn_merge::stream::{stream_ingest_into, IngestOptions, StreamingIndex};
use std::sync::Arc;

fn main() {
    // 1. A SIFT-like stream of 10k vectors, arriving in row order.
    let n = 10_000;
    let ds = DatasetFamily::Sift.generate(n, 42);
    let queries = DatasetFamily::Sift.generate_queries(50, 7);
    println!("stream: {} vectors, dim {}", ds.len(), ds.dim);

    // 2. Segment-log configuration: 1k-vector segments, merge-based
    //    compaction with the batch pipeline's own k. Each vector is
    //    merged O(log n) times, so a slightly wider lambda + tighter
    //    delta keeps every compaction fully converged.
    let cfg = StreamConfig {
        segment_size: 1_000,
        merge: MergeParams {
            k: 20,
            lambda: 16,
            delta: 5e-4,
            ..Default::default()
        },
        nnd: NnDescentParams {
            k: 20,
            lambda: 12,
            ..Default::default()
        },
        ..Default::default()
    };

    // 3. Ingest while searching: every 2k inserts a 50-query batch runs
    //    against the live index (memtable + segments) and is scored
    //    against exact truth over the inserted prefix. Compaction runs
    //    concurrently on a background thread.
    let opts = IngestOptions {
        report_every: 2_000,
        background_compaction: true,
        ..Default::default()
    };
    let index = Arc::new(StreamingIndex::new(ds.dim, Metric::L2, cfg));
    let summary = stream_ingest_into(&index, &ds, &queries, &opts, &mut |row| {
        println!(
            "  t={:6.2}s  inserted {:>6}  segments {:>2}  qps {:>7.0}  recall@10 {:.4}",
            row.elapsed_s, row.inserted, row.segments, row.qps, row.recall
        );
    });
    println!(
        "ingest done: {:.0} inserts/s, {} compactions, {} final segment(s)",
        summary.insert_rate, summary.compactions, summary.segments
    );

    // 4. Parity check: the streamed-and-compacted graph vs. a batch
    //    NN-Descent build of the same data (graph recall@10, same
    //    sampled ground truth for both).
    let snap = index.snapshot();
    assert_eq!(snap.count(), 1, "final compaction should leave one segment");
    let streamed = snap.segments[0].knn_in_global_space();
    let batch = NnDescent::new(NnDescentParams {
        k: 20,
        lambda: 12,
        ..Default::default()
    })
    .build(&ds, Metric::L2);
    let truth = GroundTruth::sampled(&ds, 10, Metric::L2, 300, 9);
    let r_stream = graph_recall(&streamed, &truth, 10);
    let r_batch = graph_recall(&batch, &truth, 10);
    println!("graph recall@10: streamed {r_stream:.4} vs batch {r_batch:.4}");
    assert!(
        r_stream >= r_batch - 0.05,
        "streamed {r_stream} must be within 0.05 of batch {r_batch}"
    );
    println!("OK: streaming build matches the batch build");
}

//! Quickstart: build two subgraphs with NN-Descent and merge them with
//! Two-way Merge (paper Alg. 1), then check the result against exact
//! ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use knn_merge::construction::{NnDescent, NnDescentParams};
use knn_merge::dataset::DatasetFamily;
use knn_merge::distance::Metric;
use knn_merge::eval::recall::{graph_recall, GroundTruth};
use knn_merge::merge::{MergeParams, TwoWayMerge};

fn main() {
    // 1. A SIFT-like synthetic dataset (d=128, LID ~ 16, see Tab. II).
    let n = 8_000;
    let ds = DatasetFamily::Sift.generate(n, 42);
    println!("dataset: {} vectors, dim {}", ds.len(), ds.dim);

    // 2. Split into two disjoint subsets and build a subgraph on each —
    //    in a real deployment these come from different machines or
    //    different ingestion batches.
    let parts = ds.split_contiguous(2);
    let nnd = NnDescent::new(NnDescentParams {
        k: 20,
        lambda: 12,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let g1 = nnd.build(&parts[0].0, Metric::L2);
    let g2 = nnd.build(&parts[1].0, Metric::L2);
    println!("subgraphs built in {:.2}s", t0.elapsed().as_secs_f64());

    // 3. Two-way Merge: one-shot sampling into the supporting graph S,
    //    flag-driven Local-Join rounds, final MergeSort with G0.
    let t1 = std::time::Instant::now();
    let merged = TwoWayMerge::new(MergeParams {
        k: 20,
        lambda: 12,
        ..Default::default()
    })
    .merge(&parts[0].0, &parts[1].0, &g1, &g2, Metric::L2);
    println!("two-way merge in {:.2}s", t1.elapsed().as_secs_f64());

    // 4. Quality check against exact (sampled) ground truth.
    let truth = GroundTruth::sampled(&ds, 10, Metric::L2, 300, 7);
    let r10 = graph_recall(&merged, &truth, 10);
    println!("merged graph recall@10 = {r10:.4}");
    assert!(r10 > 0.9, "quickstart should reach recall@10 > 0.9");
    println!("OK");
}

"""Layer 2 — the JAX compute graph lowered for the Rust coordinator.

Two exported entry points, both calling the L1 Pallas kernel so they
lower into the same HLO module family:

  * ``cross_distance`` — the batched distance tile evaluator the Rust
    Local-Join hot path dispatches to (`runtime::XlaEngine`).
  * ``distance_topk`` — distance tiles fused with a top-k selection,
    the "candidate shortlist" graph used by the GNND-style baseline and
    kept as a demonstration that L2 composes on top of L1 (XLA fuses
    the top-k with the kernel output without an HBM round-trip of the
    full distance tile).

Build-time only: `aot.py` lowers these with fixed shapes into
`artifacts/*.hlo.txt`; nothing here is imported at runtime.
"""

import jax

from .kernels.l2_distance import batched_cross_l2


def cross_distance(x, y):
    """x: [B, NX, D], y: [B, NY, D] -> ([B, NX, NY],)"""
    return (batched_cross_l2(x, y),)


def distance_topk(x, y, *, k):
    """Fused distance + k-smallest selection.

    Returns (dists [B, NX, k] ascending, indices [B, NX, k] into NY).
    """
    d = batched_cross_l2(x, y)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx

"""AOT lowering: JAX (L2+L1) -> HLO text -> artifacts/.

HLO *text* is the interchange format (NOT ``lowered.compile()`` or a
serialized ``HloModuleProto``): jax >= 0.5 emits protos with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage (normally via ``make artifacts``):

    python -m compile.aot --out-dir ../artifacts \
        --dims 128,96,100,960 --batch 64 --nx 32 --ny 32

Artifact naming is consumed by ``rust/src/runtime/mod.rs``:
``l2xdist_b{B}_x{NX}_y{NY}_d{D}.hlo.txt``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cross_distance(b, nx, ny, d) -> str:
    x = jax.ShapeDtypeStruct((b, nx, d), jnp.float32)
    y = jax.ShapeDtypeStruct((b, ny, d), jnp.float32)
    return to_hlo_text(jax.jit(model.cross_distance).lower(x, y))


def lower_distance_topk(b, nx, ny, d, k) -> str:
    x = jax.ShapeDtypeStruct((b, nx, d), jnp.float32)
    y = jax.ShapeDtypeStruct((b, ny, d), jnp.float32)
    fn = lambda x, y: model.distance_topk(x, y, k=k)
    return to_hlo_text(jax.jit(fn).lower(x, y))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dims", default="128",
                    help="comma-separated vector dims to compile for")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--ny", type=int, default=32)
    ap.add_argument("--topk", type=int, default=0,
                    help="also emit the fused distance+topk artifact")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for d in [int(t) for t in args.dims.split(",") if t]:
        name = f"l2xdist_b{args.batch}_x{args.nx}_y{args.ny}_d{d}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_cross_distance(args.batch, args.nx, args.ny, d)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
        if args.topk:
            tname = (f"topk{args.topk}_b{args.batch}_x{args.nx}"
                     f"_y{args.ny}_d{d}.hlo.txt")
            tpath = os.path.join(args.out_dir, tname)
            text = lower_distance_topk(args.batch, args.nx, args.ny, d,
                                       args.topk)
            with open(tpath, "w") as f:
                f.write(text)
            print(f"wrote {tpath} ({len(text)} chars)")


if __name__ == "__main__":
    main()

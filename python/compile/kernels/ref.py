"""Pure-jnp oracle for the L1 distance kernel.

The kernel computes batched squared-L2 cross-distance tiles:

    D[b, i, j] = || X[b, i, :] - Y[b, j, :] ||^2

Two reference implementations are provided: the direct difference form
(numerically exact, the correctness oracle) and the norm-expanded form
(what the Pallas kernel computes on the MXU, used to bound the
cancellation error accepted from the fast path).
"""

import jax
import jax.numpy as jnp


def cross_l2_direct(x, y):
    """Direct sum((x - y)^2) — the oracle.

    x: [B, NX, D], y: [B, NY, D] -> [B, NX, NY] (float32)
    """
    diff = x[:, :, None, :] - y[:, None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def cross_l2_expanded(x, y):
    """Norm expansion ||x||^2 + ||y||^2 - 2 x.y (MXU-friendly form)."""
    xn = jnp.sum(x * x, axis=-1)  # [B, NX]
    yn = jnp.sum(y * y, axis=-1)  # [B, NY]
    xy = jnp.einsum("bid,bjd->bij", x, y)
    d = xn[:, :, None] + yn[:, None, :] - 2.0 * xy
    return jnp.maximum(d, 0.0)


def topk_neighbors(x, y, k):
    """Reference for the L2 model's fused distance + top-k stage.

    Returns (dists, idx): the k smallest distances per (b, i) row and the
    corresponding Y indices, ascending by distance.
    """
    d = cross_l2_direct(x, y)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx

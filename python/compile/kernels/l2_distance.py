"""Layer 1 — the Pallas cross-distance kernel.

The Local-Join hot spot of the merge algorithms is a batch of small
cross-distance tiles: every sampled neighbor of an element against every
newly discovered one. On GPU (GNND) this is a shared-memory threadblock
tile + WMMA matmul; the TPU mapping (DESIGN.md §Hardware-Adaptation) is:

  * grid over the batch of tiles; per step, `BlockSpec` stages one
    X tile `[NX, D]` and one Y tile `[NY, D]` from HBM into VMEM;
  * the `X @ Y^T` contraction targets the MXU
    (`preferred_element_type=float32`);
  * the rank-1 norm corrections are VPU element-wise ops fused in the
    same kernel, so the `[NX, NY]` result is written once — no HBM
    round-trip for intermediates.

`interpret=True` is mandatory on CPU-PJRT: real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute. The structure (tiling,
fusion, memory schedule) is what carries to hardware; see
EXPERIMENTS.md §Perf for the analytic VMEM/MXU estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_tile_kernel(x_ref, y_ref, o_ref):
    """One grid step: squared-L2 distances of one [NX, D] x [NY, D] tile.

    Refs arrive blocked as [1, NX, D] / [1, NY, D] / [1, NX, NY].
    """
    x = x_ref[0]  # [NX, D] in VMEM
    y = y_ref[0]  # [NY, D] in VMEM
    # MXU contraction: X @ Y^T with f32 accumulation.
    xy = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # VPU: rank-1 norm corrections, fused in the same kernel.
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # [NX, 1]
    yn = jnp.sum(y * y, axis=1, keepdims=True).T  # [1, NY]
    d = xn + yn - 2.0 * xy
    # Cancellation can push exact zeros slightly negative.
    o_ref[0] = jnp.maximum(d, 0.0)


def _l2_batch_kernel(x_ref, y_ref, o_ref):
    """Whole-batch variant: one grid step over [B, NX, D] x [B, NY, D].

    Same arithmetic as `_l2_tile_kernel`, batched with dot_general over
    the shared leading dim (batch matmul hits the MXU per slice).
    """
    x = x_ref[...]
    y = y_ref[...]
    xy = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    xn = jnp.sum(x * x, axis=2)[:, :, None]
    yn = jnp.sum(y * y, axis=2)[:, None, :]
    o_ref[...] = jnp.maximum(xn + yn - 2.0 * xy, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret", "grid_over_batch"))
def batched_cross_l2(x, y, *, interpret=True, grid_over_batch=False):
    """Batched squared-L2 cross distances via the Pallas tile kernel.

    x: [B, NX, D] float32, y: [B, NY, D] float32 -> [B, NX, NY] float32.

    ``grid_over_batch=True`` is the TPU schedule: one grid step per batch
    element, each staging a [NX, D]+[NY, D] tile HBM->VMEM (`vmem_bytes`
    sizes it). On CPU-PJRT the interpreter executes grid steps as a
    serialized loop with per-step overhead, so the AOT artifact for the
    CPU runtime uses the single-block variant (`grid_over_batch=False`),
    whose one step is the same fused arithmetic over the whole batch.
    Both paths share the oracle tests.
    """
    b, nx, d = x.shape
    _, ny, _ = y.shape
    if grid_over_batch:
        return pl.pallas_call(
            _l2_tile_kernel,
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, nx, d), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, ny, d), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, nx, ny), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, nx, ny), jnp.float32),
            interpret=interpret,
        )(x, y)
    return pl.pallas_call(
        _l2_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((b, nx, ny), jnp.float32),
        interpret=interpret,
    )(x, y)


def vmem_bytes(nx, ny, d, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step (perf model §Perf).

    X tile + Y tile + output tile, all resident simultaneously.
    """
    return dtype_bytes * (nx * d + ny * d + nx * ny)


def mxu_utilization_estimate(nx, ny, d, mxu=128):
    """Fraction of MXU lanes busy for the X @ Y^T contraction.

    The 128x128 systolic array is fed [NX, D] x [D, NY] — utilization is
    the product of the fill ratios of each dimension (padded to the MXU
    tile). This is the structural estimate used to pick tile shapes; it
    is exact for dense tiles and an upper bound under padding.
    """
    fill = lambda n: n / (((n + mxu - 1) // mxu) * mxu)
    return fill(nx) * fill(ny) * fill(d)

"""L2 model + AOT pipeline tests: shapes, top-k fusion, HLO text
generation (the artifact the Rust runtime loads)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


def test_cross_distance_shape_and_tuple():
    x = jnp.asarray(rand((2, 8, 16), 0))
    y = jnp.asarray(rand((2, 6, 16), 1))
    out = model.cross_distance(x, y)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (2, 8, 6)


def test_distance_topk_matches_reference():
    x = jnp.asarray(rand((2, 8, 16), 2))
    y = jnp.asarray(rand((2, 12, 16), 3))
    d_got, i_got = model.distance_topk(x, y, k=4)
    d_want, i_want = ref.topk_neighbors(x, y, 4)
    np.testing.assert_allclose(np.asarray(d_got), np.asarray(d_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i_got), np.asarray(i_want))


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 8), ny=st.integers(8, 20), seed=st.integers(0, 999))
def test_topk_is_sorted_and_within_range(k, ny, seed):
    x = jnp.asarray(rand((1, 4, 8), seed))
    y = jnp.asarray(rand((1, ny, 8), seed + 1))
    d, i = model.distance_topk(x, y, k=k)
    d = np.asarray(d)
    i = np.asarray(i)
    assert d.shape == (1, 4, k)
    assert (np.diff(d, axis=-1) >= -1e-6).all(), "distances ascending"
    assert (i >= 0).all() and (i < ny).all()


def test_hlo_text_lowering_smoke():
    text = aot.lower_cross_distance(2, 4, 4, 8)
    assert "HloModule" in text
    # The lowered module must expose the two parameters and a tuple root.
    assert "f32[2,4,8]" in text
    assert "f32[2,4,4]" in text


def test_hlo_text_topk_lowering_smoke():
    text = aot.lower_distance_topk(2, 4, 6, 8, 3)
    assert "HloModule" in text
    assert "f32[2,4,3]" in text or "s32[2,4,3]" in text

"""L1 kernel correctness: Pallas (interpret) vs the pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; numpy brute force
pins the oracle itself.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.l2_distance import (
    batched_cross_l2,
    mxu_utilization_estimate,
    vmem_bytes,
)


def numpy_cross_l2(x, y):
    b, nx, d = x.shape
    _, ny, _ = y.shape
    out = np.zeros((b, nx, ny), dtype=np.float64)
    for t in range(b):
        for i in range(nx):
            for j in range(ny):
                diff = x[t, i].astype(np.float64) - y[t, j].astype(np.float64)
                out[t, i, j] = np.dot(diff, diff)
    return out


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def test_oracle_matches_numpy():
    x = rand((2, 3, 5), 1)
    y = rand((2, 4, 5), 2)
    got = np.asarray(ref.cross_l2_direct(jnp.asarray(x), jnp.asarray(y)))
    want = numpy_cross_l2(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_expanded_form_matches_oracle():
    x = rand((3, 8, 32), 3)
    y = rand((3, 8, 32), 4)
    a = np.asarray(ref.cross_l2_direct(jnp.asarray(x), jnp.asarray(y)))
    b = np.asarray(ref.cross_l2_expanded(jnp.asarray(x), jnp.asarray(y)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_pallas_kernel_matches_oracle_basic():
    x = rand((4, 16, 64), 5)
    y = rand((4, 16, 64), 6)
    got = np.asarray(batched_cross_l2(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.cross_l2_direct(jnp.asarray(x), jnp.asarray(y)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    nx=st.integers(1, 16),
    ny=st.integers(1, 16),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_pallas_kernel_matches_oracle_property(b, nx, ny, d, seed, scale):
    x = rand((b, nx, d), seed, scale)
    y = rand((b, ny, d), seed + 1, scale)
    got = np.asarray(batched_cross_l2(jnp.asarray(x), jnp.asarray(y)))
    want = numpy_cross_l2(x, y)
    tol = max(1e-4, 1e-5 * scale * scale * d)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=tol)


def test_kernel_nonnegative_and_zero_diagonal():
    x = rand((2, 8, 16), 7)
    got = np.asarray(batched_cross_l2(jnp.asarray(x), jnp.asarray(x)))
    assert (got >= 0.0).all()
    for t in range(2):
        np.testing.assert_allclose(np.diag(got[t]), 0.0, atol=1e-3)


def test_kernel_identical_rows_give_zero():
    x = np.ones((1, 4, 8), dtype=np.float32) * 3.0
    got = np.asarray(batched_cross_l2(jnp.asarray(x), jnp.asarray(x)))
    np.testing.assert_allclose(got, 0.0, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32])
def test_kernel_dtype_is_f32(dtype):
    x = rand((1, 4, 8), 8).astype(dtype)
    out = batched_cross_l2(jnp.asarray(x), jnp.asarray(x))
    assert out.dtype == jnp.float32


def test_vmem_model():
    # 32x32 tile at d=128: X 16 KiB + Y 16 KiB + out 4 KiB = 36 KiB.
    assert vmem_bytes(32, 32, 128) == 4 * (32 * 128 + 32 * 128 + 32 * 32)
    # Must fit a TPU core's ~16 MiB VMEM with generous headroom.
    assert vmem_bytes(32, 32, 960) < 16 * 2**20


def test_mxu_estimate_monotone():
    # Full 128-wide tiles use the array fully.
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    # Smaller tiles waste lanes.
    assert mxu_utilization_estimate(32, 32, 128) == pytest.approx(
        (32 / 128) ** 2
    )
    assert mxu_utilization_estimate(32, 32, 96) < mxu_utilization_estimate(
        32, 32, 128
    )

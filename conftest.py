"""Pytest shim: make `pytest python/tests/` and `pytest scripts/tests/`
work from the repo root by putting the build-time python package
(python/compile) and the static-analysis package (scripts/knnlint) on
the path."""
import os
import sys

_here = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_here, "python"))
sys.path.insert(0, os.path.join(_here, "scripts"))
